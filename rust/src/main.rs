//! `pqs` — CLI for the PQS (Prune, Quantize, and Sort) reproduction.
//!
//! Subcommands:
//!   list                         list trained models from the manifest
//!        (with each entry's on-disk byte size and weight content hash)
//!   describe --model NAME        model summary (layers, dot lengths, sparsity)
//!   eval --model NAME [--policy sorted|clip|wrap|sorted1|oracle|exact]
//!        [--acc-bits P] [--tile K] [--limit N] [--stats] [--batch B]
//!   profile --model NAME --acc-bits P [--limit N]
//!        per-layer transient/persistent overflow profile
//!   runtime --hlo PATH [--n N]   run an AOT HLO artifact through PJRT
//!   figures [--fig 2|3|4|5|6]    regenerate the paper figures
//!   plan [--model SPEC] [--policy P] [--calibrate N] [--budget F]
//!        [--margin B] [--batch B] [--seed S] [--emit PATH.pqsw]
//!        accumulator-bitwidth planner: per-layer analytic worst-case
//!        widths (guaranteed overflow-free, see pqs::plan) plus — with
//!        --calibrate N — empirically tightened widths from N sample
//!        inputs (binary-searched against --budget, padded by --margin
//!        safety bits, capped at the analytic bound). Calibration uses
//!        the real test set when the artifacts provide one matching the
//!        model's input shape, else deterministic synthetic inputs.
//!        Prints the
//!        per-layer table and the total accumulator-bit savings vs a
//!        32-bit baseline. SPEC is as for serve-http --model (default:
//!        a synthetic CNN, so the command runs without artifacts).
//!        --emit writes a .pqsw with the plan embedded as a versioned
//!        section; serving that file enforces the per-layer widths and
//!        reports the plan in GET /v1/models.
//!   project --budget N [--nm N:M] [--model SPEC] [--policy P]
//!        [--emit PATH.pqsw]
//!        the planner's inverse (see pqs::sweep): edit the quantized
//!        weights so every layer's analytic accumulator bound fits
//!        --budget bits under --policy — optional N:M pruning first
//!        (keep the N largest-magnitude weights per group of M), then
//!        per-row integer soft-thresholding (the ℓ1-projection step) —
//!        and print the per-layer before/after table. --emit writes the
//!        projected model with its analytic plan embedded (checksummed
//!        v2 .pqsw; the serving path enforces the widths unchanged).
//!   sweep [--model SPEC] [--policy P] [--budgets LIST] [--nm LIST]
//!        [--samples N] [--batch B] [--threads T] [--tolerance F]
//!        [--seed S] [--json PATH] [--gate]
//!        walk the (budget × N:M) grid: project each candidate, evaluate
//!        accuracy through EvalService, print the accuracy-vs-width
//!        Pareto table and optionally write the frontier JSON (schema in
//!        the pqs::sweep module docs). --budgets takes integers or
//!        "max"/"max-K" tokens resolved against the unprojected model's
//!        widest analytic layer (default "max,max-1,max-2"); --nm is a
//!        comma list of "dense" and "N:M" specs (default dense).
//!        Evaluates on the real test set when the artifacts provide a
//!        matching one (--samples caps it), else on a seeded synthetic
//!        set labeled by the unprojected model at 32-bit exact
//!        arithmetic, so accuracy reads as agreement with the wide
//!        reference and the baseline scores 1.0. Exits nonzero if any
//!        point violates its budget or records a persistent overflow
//!        (broken guarantee); --gate additionally fails points whose
//!        accuracy drops more than --tolerance below the baseline.
//!   serve-http [--addr HOST:PORT] [--model NAME[=SPEC[,OPTS]]]...
//!        [--max-loaded M] [--max-bytes B] [--preload NAME]...
//!        [--threads N] [--engine-threads T]
//!        [--max-batch B] [--queue-cap Q] [--deadline-ms MS] [--for-secs S]
//!        [--event-loop on|off] [--max-connections N]
//!        [--trace-sample-rate F] [--trace-ring N]
//!        multi-model HTTP/1.1 front-end over the serving router
//!        (POST /v1/classify with optional "model" and "acc_bits" fields,
//!        GET /v1/models, GET /v1/metrics, GET /v1/trace, GET /metrics
//!        in Prometheus text format, GET /healthz — see the `pqs::http`
//!        module docs for the wire protocol and the X-Request-Id
//!        contract). --trace-sample-rate sets the head-sampling
//!        probability for the request-trace ring (default 0: stage
//!        histograms and id echo still on; errors, overflows and sheds
//!        are always ring-kept) and --trace-ring its span capacity.
//!        `--model` repeats; the first is the default route. Each SPEC is
//!        `linear:<dim>x<classes>`, `conv:<c>x<h>x<w>x<oc>x<classes>`, a
//!        `.pqsw` path, or (bare name / no SPEC) a manifest entry loaded
//!        lazily on first request; trailing `,acc_bits=N` / `,threads=M`
//!        OPTS attach per-model engine overrides. Without any `--model`:
//!        every manifest model is registered (artifacts present), else
//!        two synthetic models. `--max-loaded` caps simultaneously-loaded
//!        models (LRU eviction; 0 = unlimited); `--max-bytes` budgets the
//!        fleet's resident weight bytes (measured, blob-deduped; loading
//!        past it LRU-evicts, a model that cannot fit alone is refused;
//!        0 = unlimited). `--preload NAME` (repeatable) loads
//!        the named models eagerly at startup instead of on first
//!        request (counted in the router's `loads`; unknown names fail
//!        startup). `--engine-threads` sizes the ONE
//!        compute pool shared by every loaded model's engines (default:
//!        hw threads, with workers defaulting to 2 so pool and workers
//!        never oversubscribe; `--engine-threads 1` restores the
//!        worker-parallel topology with hw workers). `--event-loop`
//!        selects the connection backend (`on` = readiness-driven epoll
//!        loop, Linux default; `off` = blocking worker pool) and
//!        `--max-connections` caps concurrently open sockets under the
//!        event loop (accepts past it shed with 503).
//!        `--fault-spec "load_error=0.1,panic_every=50,..."` arms seeded
//!        fault injection for chaos testing (`--fault-seed N` replays a
//!        schedule); the self-healing surface — load circuit breakers,
//!        integrity quarantine, panic isolation, `GET /readyz` — is
//!        documented in the `pqs::http` and `pqs::faults` module docs
//!   bench [--json PATH] [--quick] [--threads "1,2,8"]
//!        machine-readable perf report (dot kernels, pool dispatch,
//!        batch-1 forward scaling with bit-identity checks, HTTP serve
//!        latency); see `pqs::benchreport`
//!
//! Run from the repo root (or set PQS_ARTIFACTS).

use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use pqs::accum::Policy;
use pqs::coordinator::{
    EvalService, ModelOverrides, ModelRegistry, ModelSource, Router, RouterConfig, ServerConfig,
    SyntheticSpec,
};
use pqs::data::Dataset;
use pqs::figures;
use pqs::formats::manifest::Manifest;
use pqs::http::{HttpConfig, HttpServer};
use pqs::models;
use pqs::nn::engine::EngineConfig;
use pqs::sweep::{NmSpec, ProjectConfig};
use pqs::util::cli::Args;
use pqs::util::pool;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parse the `--budgets` grid axis: comma-separated integers or
/// `max`/`max-K` tokens resolved against the unprojected model's widest
/// analytic layer (floored at 2 bits).
fn parse_budgets(s: &str, analytic_max: u32) -> Result<Vec<u32>> {
    let mut out = Vec::new();
    for tok in s.split(',') {
        let t = tok.trim();
        if t.is_empty() {
            continue;
        }
        let v = if let Some(rest) = t.strip_prefix("max") {
            let sub: u32 = if rest.is_empty() {
                0
            } else {
                rest.strip_prefix('-')
                    .and_then(|r| r.trim().parse().ok())
                    .ok_or_else(|| anyhow!("bad budget token {t:?} (use N, max, or max-K)"))?
            };
            analytic_max.saturating_sub(sub).max(2)
        } else {
            t.parse().map_err(|_| anyhow!("bad budget token {t:?} (use N, max, or max-K)"))?
        };
        out.push(v);
    }
    if out.is_empty() {
        bail!("--budgets lists no budgets");
    }
    Ok(out)
}

fn engine_cfg(args: &Args) -> Result<EngineConfig> {
    let policy = Policy::from_name(args.get_or("policy", "sorted"))
        .ok_or_else(|| anyhow!("unknown policy (use one of exact|clip|wrap|sorted1|sorted|oracle)"))?;
    Ok(EngineConfig {
        policy,
        acc_bits: args.get_u32("acc-bits", 16),
        tile: args.get_usize("tile", 0),
        collect_stats: args.has("stats"),
    })
}

fn run() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "list" => {
            let man = Manifest::load_default()?;
            println!(
                "{:<46} {:<8} {:>6} {:>8} {:>8} {:>10} {:>10} {:<16}",
                "name", "schedule", "w/a", "sparsity", "acc(py)", "plan", "bytes", "hash"
            );
            for (_, e) in &man.models {
                let plan = match &e.plan {
                    Some(p) => format!("{}..{}b", p.min_bits, p.max_bits),
                    None => "-".to_string(),
                };
                // on-disk size + weight content hash ("-" when the file is
                // missing or unreadable; the hash pays one lazy load)
                let path = man.model_path(&e.name);
                let bytes = std::fs::metadata(&path)
                    .map(|md| md.len().to_string())
                    .unwrap_or_else(|_| "-".to_string());
                let hash = pqs::formats::pqsw::PqswModel::load(&path)
                    .map(|m| format!("{:016x}", m.content_hash()))
                    .unwrap_or_else(|_| "-".to_string());
                println!(
                    "{:<46} {:<8} {:>3}/{:<3} {:>7.1}% {:>8.3} {:>10} {:>10} {:<16}",
                    e.name, e.schedule, e.wbits, e.abits, 100.0 * e.achieved_sparsity, e.acc_q,
                    plan, bytes, hash
                );
            }
            for (exp, names) in &man.experiments {
                println!("experiment {exp}: {} models", names.len());
            }
        }
        "describe" => {
            let man = Manifest::load_default()?;
            let name = args.get("model").ok_or_else(|| anyhow!("--model required"))?;
            let m = models::load(&man, name)?;
            println!("{}", models::describe(&m));
            println!(
                "max dot length {} (effective after pruning {})",
                models::max_dot_length(&m),
                models::max_effective_dot_length(&m)
            );
        }
        "eval" => {
            let man = Manifest::load_default()?;
            let name = args.get("model").ok_or_else(|| anyhow!("--model required"))?;
            let model = models::load(&man, name)?;
            let cfg = engine_cfg(&args)?;
            let entry = man.test_dataset_for(&model.arch)?;
            let ds = Dataset::load(man.dataset_path(&entry.test))?;
            let limit = args.get_usize("limit", ds.n);
            let svc = EvalService::new(&model, cfg).with_batch(args.get_usize("batch", 64));
            let out = svc.evaluate(&ds, Some(limit))?;
            println!(
                "model={name} policy={} p={} tile={} samples={} accuracy={:.4} ({:.1} img/s, {:.0} ms)",
                cfg.policy.name(), cfg.acc_bits, cfg.tile, out.samples, out.accuracy,
                out.throughput_ips, out.wall_ms
            );
            if cfg.collect_stats {
                out.report.print();
            }
        }
        "profile" => {
            let man = Manifest::load_default()?;
            let name = args.get("model").ok_or_else(|| anyhow!("--model required"))?;
            let model = models::load(&man, name)?;
            let mut cfg = engine_cfg(&args)?;
            cfg.collect_stats = true;
            let entry = man.test_dataset_for(&model.arch)?;
            let ds = Dataset::load(man.dataset_path(&entry.test))?;
            let limit = args.get_usize("limit", 128);
            let out = EvalService::new(&model, cfg).evaluate(&ds, Some(limit))?;
            println!(
                "model={name} policy={} p={} samples={} accuracy={:.4}",
                cfg.policy.name(), cfg.acc_bits, out.samples, out.accuracy
            );
            out.report.print();
        }
        "runtime" => {
            let man = Manifest::load_default()?;
            let hlo = args.get("hlo").map(String::from).unwrap_or_else(|| {
                man.dir.join("model.hlo.txt").display().to_string()
            });
            let rt = pqs::runtime::Runtime::cpu()?;
            println!("PJRT platform: {}", rt.platform());
            let exe = rt.load_hlo(&hlo)?;
            // feed the first 8 mnist test images
            let entry = man.test_dataset_for("mlp1")?;
            let ds = Dataset::load(man.dataset_path(&entry.test))?;
            let imgs = ds.images_f32(0, 8);
            let outs = exe.run_f32(&imgs, &[8, 1, 28, 28])?;
            println!("outputs: {} tensors", outs.len());
            for (i, o) in outs.iter().enumerate() {
                let head: Vec<String> = o.iter().take(10).map(|v| format!("{v:.3}")).collect();
                println!("  out[{i}] len={} head=[{}]", o.len(), head.join(", "));
            }
        }
        "figures" => {
            let man = Manifest::load_default()?;
            let which = args.get_or("fig", "all").to_string();
            let limit = figures::eval_limit(256);
            if which == "2" || which == "all" {
                let r = figures::fig2::run(&man, limit, 12..=20)?;
                figures::fig2::print(&r);
            }
            if which == "3" || which == "all" {
                let rows = figures::fig3::run(&man, limit, 8)?;
                figures::fig3::print(&rows);
            }
            if which == "4" || which == "all" {
                let rows = figures::fig4::run(&man, limit.min(128), 6)?;
                figures::fig4::print(&rows);
            }
            if which == "5" || which == "all" {
                let pts = figures::fig5::run(&man, limit.min(192), &[12, 13, 14, 16, 20], None)?;
                figures::fig5::print(&pts);
            }
            if which == "6" || which == "all" {
                if let Some(name) = figures::sec6::default_model(&man) {
                    let r = figures::sec6::run(&man, &name, 16, &[16, 64, 256, 0], limit.min(64))?;
                    figures::sec6::print(&r);
                }
            }
        }
        "plan" => {
            let manifest = Manifest::load_default().ok();
            let model = match args.get("model") {
                Some(spec) => ModelSource::parse(spec, manifest.as_ref())?.load()?,
                // default: the synthetic CNN — the planner is demonstrable
                // on any checkout, artifacts or not
                None => pqs::models::synthetic_conv(3, 28, 28, 8, 10),
            };
            let policy = Policy::from_name(args.get_or("policy", "sorted")).ok_or_else(|| {
                anyhow!("unknown policy (use one of exact|clip|wrap|sorted1|sorted|oracle)")
            })?;
            let pcfg = pqs::plan::PlannerConfig {
                policy,
                calibrate_samples: args.get_usize("calibrate", 0),
                budget: args.get_f64("budget", 0.0),
                margin: args.get_u32("margin", 1),
                batch: args.get_usize("batch", 32),
                seed: args.get_u32("seed", 0x9A17) as u64,
            };
            println!("planning {} ({} q-layers)", model.name, model.q_layers().count());
            // calibrate on the real test set when the artifacts provide one
            // that fits this model; otherwise plan_model_observed falls
            // back to the planner's deterministic synthetic probe
            let dim: usize = model.input_shape.iter().product();
            let observed = if pcfg.calibrate_samples > 0 {
                let real = manifest.as_ref().and_then(|man| {
                    let entry = man.test_dataset_for(&model.arch).ok()?;
                    let ds = Dataset::load(man.dataset_path(&entry.test)).ok()?;
                    (ds.dim() == dim && ds.n > 0).then_some((entry.test.clone(), ds))
                });
                match real {
                    Some((file, ds)) => {
                        let n = pcfg.calibrate_samples.min(ds.n);
                        let batch = pcfg.batch.max(1);
                        let mut batches: Vec<(Vec<f32>, usize)> = Vec::new();
                        let mut off = 0;
                        while off < n {
                            let b = batch.min(n - off);
                            batches.push((ds.images_f32(off, b), b));
                            off += b;
                        }
                        println!("calibrating on {n} real samples from {file}");
                        Some(pqs::plan::observe_batches(
                            &model,
                            policy,
                            batches.iter().map(|(v, b)| (v.as_slice(), *b)),
                        )?)
                    }
                    None => {
                        println!("(no matching real dataset; calibrating on synthetic inputs)");
                        None
                    }
                }
            } else {
                None
            };
            let t0 = std::time::Instant::now();
            let plan = pqs::plan::plan_model_observed(&model, &pcfg, observed.as_ref())?;
            println!("planner ran in {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
            plan.print();
            if let Some(path) = args.get("emit") {
                let mut planned = model.clone();
                planned.plan = Some(plan);
                planned.save(path)?;
                println!(
                    "wrote {path} with the plan embedded (a router serving it enforces \
                     the per-layer widths and reports them in GET /v1/models)"
                );
            }
        }
        "project" => {
            let manifest = Manifest::load_default().ok();
            let mut model = match args.get("model") {
                Some(spec) => ModelSource::parse(spec, manifest.as_ref())?.load()?,
                None => pqs::models::synthetic_conv(3, 28, 28, 8, 10),
            };
            let policy = Policy::from_name(args.get_or("policy", "sorted")).ok_or_else(|| {
                anyhow!("unknown policy (use one of exact|clip|wrap|sorted1|sorted|oracle)")
            })?;
            let budget = args.get_u32("budget", 0);
            if budget == 0 {
                bail!("pqs project requires --budget N (the target accumulator width in bits)");
            }
            let nm = match args.get("nm") {
                Some(s) => NmSpec::parse(s)?,
                None => None,
            };
            let t0 = std::time::Instant::now();
            let rep = pqs::sweep::project(&mut model, &ProjectConfig { policy, budget, nm })?;
            println!(
                "projected {} in {:.1} ms ({})",
                model.name,
                t0.elapsed().as_secs_f64() * 1e3,
                if rep.changed() { "weights edited" } else { "already within budget" },
            );
            rep.print();
            if let Some(plan) = &model.plan {
                plan.print();
            }
            if let Some(path) = args.get("emit") {
                model.save(path)?;
                println!(
                    "wrote {path} with projected weights + plan embedded (a router serving \
                     it enforces the per-layer widths and reports them in GET /v1/models)"
                );
            }
        }
        "sweep" => {
            let manifest = Manifest::load_default().ok();
            let model = match args.get("model") {
                Some(spec) => ModelSource::parse(spec, manifest.as_ref())?.load()?,
                None => pqs::models::synthetic_conv(3, 28, 28, 8, 10),
            };
            let policy = Policy::from_name(args.get_or("policy", "sorted")).ok_or_else(|| {
                anyhow!("unknown policy (use one of exact|clip|wrap|sorted1|sorted|oracle)")
            })?;
            let analytic_max = pqs::sweep::max_analytic_bits(&model, policy)?;
            let budgets = match args.get("budgets") {
                Some(s) => parse_budgets(s, analytic_max)?,
                None => Vec::new(), // pareto derives [max, max-1, max-2]
            };
            let nm: Vec<Option<NmSpec>> = match args.get("nm") {
                Some(s) => s.split(',').map(NmSpec::parse).collect::<Result<_>>()?,
                None => vec![None],
            };
            let samples = args.get_usize("samples", 256).max(1);
            let mut cfg = pqs::sweep::SweepConfig {
                policy,
                budgets,
                nm,
                batch: args.get_usize("batch", 64),
                threads: args.get_usize("threads", pool::default_threads()),
                tolerance: args.get_f64("tolerance", 0.05),
                limit: None,
            };
            // evaluate on the real test set when the artifacts provide one
            // matching this model, else on the self-labeled reference set
            let dim: usize = model.input_shape.iter().product();
            let real = manifest.as_ref().and_then(|man| {
                let entry = man.test_dataset_for(&model.arch).ok()?;
                let ds = Dataset::load(man.dataset_path(&entry.test)).ok()?;
                (ds.dim() == dim && ds.n > 0).then_some((entry.test.clone(), ds))
            });
            let ds = match real {
                Some((file, ds)) => {
                    println!("evaluating on {} real samples from {file}", samples.min(ds.n));
                    cfg.limit = Some(samples);
                    ds
                }
                None => {
                    println!(
                        "(no matching real dataset; scoring agreement with the 32-bit \
                         reference on {samples} synthetic samples)"
                    );
                    let seed = args.get_u32("seed", 0x51EE9) as u64;
                    pqs::sweep::reference_dataset(&model, samples, seed)?
                }
            };
            let t0 = std::time::Instant::now();
            let res = pqs::sweep::pareto(&model, &ds, &cfg)?;
            println!(
                "swept {} grid points in {:.1} ms",
                res.points.len(),
                t0.elapsed().as_secs_f64() * 1e3
            );
            res.print();
            if let Some(path) = args.get("json") {
                std::fs::write(path, res.to_json().to_string())?;
                println!("wrote sweep JSON to {path}");
            }
            // broken guarantees always fail; accuracy loss past the
            // declared tolerance fails under --gate (the CI smoke)
            for p in &res.points {
                let label = format!("budget {} nm {}", p.budget, NmSpec::label(p.nm));
                if !p.budget_ok {
                    bail!("{label}: enforced width {} exceeds the budget", p.width_bits);
                }
                if p.persistent_dots > 0 {
                    bail!("{label}: {} persistent dots at the planned width", p.persistent_dots);
                }
                if args.has("gate") && !p.accuracy_ok {
                    bail!(
                        "{label}: accuracy {:.4} fell more than the declared tolerance {} \
                         below the 32-bit baseline {:.4}",
                        p.accuracy,
                        res.tolerance,
                        res.baseline_accuracy
                    );
                }
            }
        }
        "serve-http" => {
            let addr = args.get_or("addr", "127.0.0.1:8090").to_string();
            let cfg = engine_cfg(&args)?;
            let manifest = Manifest::load_default().ok();
            // build the model fleet: repeated --model name[=SPEC] flags, or
            // a whole-manifest / synthetic default so the front-end is
            // always demonstrable (artifacts or not)
            let mut registry = ModelRegistry::new();
            let specs = args.get_all("model");
            if specs.is_empty() {
                match &manifest {
                    Some(man) => {
                        // default route: the fig2 lead model when present
                        for name in man.model_names() {
                            let src = ModelSource::Manifest {
                                manifest: man.clone(),
                                name: name.to_string(),
                            };
                            registry.register(name, src);
                        }
                        let lead = man.experiments.get("fig2").and_then(|v| v.first());
                        if let Some(first) = lead {
                            if let Err(e) = registry.set_default(first) {
                                // a manifest whose fig2 lead is not among its
                                // models is suspicious — say which model will
                                // serve default traffic instead of silently
                                // picking one
                                eprintln!(
                                    "warning: fig2 lead model is not registered ({e:#}); \
                                     default route is {:?}",
                                    registry.default_name().unwrap_or("?")
                                );
                            }
                        }
                    }
                    None => {
                        eprintln!("(artifacts not available — serving synthetic models)");
                        let dim = args.get_usize("dim", 784);
                        let classes = args.get_usize("classes", 10);
                        registry.register(
                            "default",
                            ModelSource::Synthetic(SyntheticSpec::Linear { dim, classes }),
                        );
                        registry.register(
                            "cnn",
                            ModelSource::Synthetic(SyntheticSpec::Conv {
                                c: 3,
                                h: 28,
                                w: 28,
                                oc: 8,
                                classes,
                            }),
                        );
                    }
                }
            } else {
                for spec in specs {
                    // --model name=SPEC[,acc_bits=N][,threads=M]: the part
                    // before the first ',' is the model spec; the rest are
                    // per-model engine overrides
                    let (name, src, ov) = match spec.split_once('=') {
                        Some((name, payload)) => {
                            let mut parts = payload.split(',');
                            let s = parts.next().unwrap_or_default();
                            let mut ov = ModelOverrides::default();
                            for kv in parts {
                                match kv.split_once('=').map(|(k, v)| (k.trim(), v.trim())) {
                                    Some(("acc_bits", v)) => {
                                        ov.acc_bits = Some(v.parse().map_err(|_| {
                                            anyhow!("bad acc_bits {v:?} in --model {spec:?}")
                                        })?);
                                    }
                                    Some(("threads", v)) => {
                                        ov.engine_threads = Some(v.parse().map_err(|_| {
                                            anyhow!("bad threads {v:?} in --model {spec:?}")
                                        })?);
                                    }
                                    _ => bail!(
                                        "unknown option {kv:?} in --model {spec:?} \
                                         (supported: acc_bits=N, threads=M)"
                                    ),
                                }
                            }
                            (name, ModelSource::parse(s, manifest.as_ref())?, ov)
                        }
                        None => (
                            spec,
                            ModelSource::parse(spec, manifest.as_ref())?,
                            ModelOverrides::default(),
                        ),
                    };
                    registry.register(name, src);
                    if !ov.is_default() {
                        registry.set_overrides(name, ov)?;
                    }
                }
            }
            if registry.is_empty() {
                bail!("no models registered; pass --model");
            }
            let deadline_ms = args.get_f64("deadline-ms", 0.0);
            // Default topology: a wide shared compute pool (batch-1 latency)
            // fed by few workers per model — with the pool on, intra-forward
            // parallelism replaces worker-level parallelism even for
            // batches (image-parallel over the pool), so more workers
            // would only contend the dispatch and oversubscribe cores.
            // `--engine-threads 1` restores the worker-parallel topology
            // (workers then default to the hw thread count). The pool is
            // ONE per process, shared by every loaded model.
            let engine_threads = args.get_usize("engine-threads", pool::default_threads());
            let scfg = ServerConfig {
                threads: args.get_usize(
                    "threads",
                    if engine_threads > 1 { 2 } else { pool::default_threads() },
                ),
                max_batch: args.get_usize("max-batch", 32),
                queue_cap: args.get_usize("queue-cap", 1024),
                linger: Duration::from_micros(200),
                engine_threads,
                default_deadline: if deadline_ms > 0.0 {
                    Some(Duration::from_secs_f64(deadline_ms / 1e3))
                } else {
                    None
                },
            };
            // --fault-spec "load_error=0.1,panic_every=50,..." arms seeded
            // fault injection (chaos testing); --fault-seed N overrides
            // the spec's seed. Production runs pass neither: the plan
            // stays None and every seam is a skipped `if let`.
            let faults = match (args.get("fault-spec"), args.get("fault-seed")) {
                (None, None) => None,
                (spec, seed) => {
                    let mut fs = match spec {
                        Some(s) => pqs::faults::FaultSpec::parse(s)?,
                        None => pqs::faults::FaultSpec::default(),
                    };
                    if let Some(s) = seed {
                        fs.seed = s.parse().map_err(|_| anyhow!("bad --fault-seed {s:?}"))?;
                    }
                    Some(std::sync::Arc::new(pqs::faults::FaultPlan::new(fs)))
                }
            };
            let rcfg = RouterConfig {
                max_loaded: args.get_usize("max-loaded", 8),
                // resident weight-byte budget for the loaded fleet
                // (0 = unlimited)
                max_bytes: args.get_usize("max-bytes", 0) as u64,
                engine: cfg,
                server: scfg,
                // eager hot-model loads (repeatable --preload NAME)
                preload: args.get_all("preload").iter().map(|s| s.to_string()).collect(),
                faults,
                ..RouterConfig::default()
            };
            let names: Vec<&str> = registry.names().collect();
            let cap = if rcfg.max_loaded == 0 {
                "unlimited".to_string()
            } else {
                rcfg.max_loaded.to_string()
            };
            let budget = if rcfg.max_bytes == 0 {
                "unlimited".to_string()
            } else {
                format!("{}B", rcfg.max_bytes)
            };
            println!(
                "serving {} model(s): {} (default {}, max loaded {cap}, byte budget {budget})",
                names.len(),
                names.join(", "),
                registry.default_name().unwrap_or("?"),
            );
            let router = Router::new(registry, rcfg)?;
            let mut hcfg = HttpConfig::default();
            if let Some(v) = args.get("event-loop") {
                hcfg.event_loop = match v {
                    "on" | "true" | "1" => true,
                    "off" | "false" | "0" => false,
                    other => bail!("bad --event-loop {other:?} (use on|off)"),
                };
            }
            hcfg.max_connections = args.get_usize("max-connections", hcfg.max_connections);
            // head-sampling probability for the trace ring; 0 keeps the
            // per-stage histograms and the X-Request-Id echo but rings
            // only errors/overflows/sheds
            hcfg.trace.sample_rate =
                args.get_f64("trace-sample-rate", hcfg.trace.sample_rate).clamp(0.0, 1.0);
            hcfg.trace.ring = args.get_usize("trace-ring", hcfg.trace.ring);
            if hcfg.event_loop && cfg!(target_os = "linux") {
                // one loop thread multiplexes every socket; lift the fd
                // soft limit toward the connection cap so mostly idle
                // keep-alive fleets aren't capped by the default 1024
                let limit = pqs::http::server::raise_nofile_limit(
                    hcfg.max_connections as u64 + 512,
                );
                if (limit as usize) < hcfg.max_connections + 64 {
                    eprintln!(
                        "warning: fd limit {limit} is below --max-connections {} + headroom; \
                         accepts may fail early",
                        hcfg.max_connections
                    );
                }
            }
            let http = HttpServer::start(router, &addr, hcfg)?;
            let backend = if hcfg.event_loop && cfg!(target_os = "linux") {
                "epoll event loop"
            } else {
                "blocking worker pool"
            };
            println!("listening on http://{} ({backend})", http.local_addr());
            println!(
                "  POST /v1/classify  {{\"image\":[...], \"model\":NAME?, \"id\":N?, \
                 \"deadline_ms\":MS?, \"acc_bits\":P?}}"
            );
            println!("  GET  /v1/models    registered models, load state, per-model metrics");
            println!("  GET  /v1/metrics   serving metrics snapshot (per-model sections)");
            println!("  GET  /v1/trace     recent request spans (?n=K; sampled + all errors)");
            println!("  GET  /metrics      Prometheus text exposition (headroom gauges)");
            println!("  GET  /healthz      liveness");
            println!("  GET  /readyz       readiness (drain state, default model, queue)");
            if let Some(f) = http.faults() {
                println!("  FAULT INJECTION ARMED: {:?}", f.spec());
            }
            let secs = args.get_f64("for-secs", 0.0);
            if secs > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(secs));
                http.shutdown().print();
            } else {
                loop {
                    std::thread::sleep(Duration::from_secs(3600));
                }
            }
        }
        "bench" => {
            let threads: Vec<usize> = args
                .get_or("threads", "1,2,8")
                .split(',')
                .filter_map(|t| t.trim().parse().ok())
                .filter(|&t| t >= 1)
                .collect();
            let opts = pqs::benchreport::BenchOptions {
                quick: args.has("quick"),
                threads: if threads.is_empty() { vec![1, 2, 8] } else { threads },
            };
            match args.get("json") {
                Some(path) => {
                    let path = path.to_string();
                    pqs::benchreport::run_to_file(&path, &opts)?;
                    println!("wrote bench report to {path}");
                }
                None => println!("{}", pqs::benchreport::run(&opts)?.to_string()),
            }
        }
        "help" => {
            println!("pqs — Prune, Quantize, and Sort (paper reproduction)");
            println!(
                "commands: list | describe | eval | profile | runtime | figures | plan | \
                 project | sweep | serve-http | bench"
            );
            println!("see rust/src/main.rs doc comment for flags");
        }
        other => bail!("unknown command {other:?} (try `pqs help`)"),
    }
    Ok(())
}
