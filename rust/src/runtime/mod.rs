//! PJRT runtime: load AOT-compiled HLO text (from `python/compile/aot.py`)
//! and execute it on the CPU PJRT client via the `xla` crate.
//!
//! This is the fast path of the stack: the same quantized computation the
//! bit-accurate engine interprets is also available as a fused XLA
//! executable built around the Layer-1 Pallas kernel
//! (`artifacts/model.hlo.txt` — sorted1 policy, 16-bit accumulator), plus
//! FP32 baselines under `artifacts/hlo/`.
//!
//! Interchange is HLO *text*: jax >= 0.5 emits protos with 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids
//! (see /opt/xla-example/README.md).

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT CPU client + compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// One compiled HLO program with a fixed input batch size.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub path: String,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file.
    pub fn load_hlo<P: AsRef<Path>>(&self, path: P) -> Result<Executable> {
        let p = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(p)
            .with_context(|| format!("parsing HLO text {p:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {p:?}"))?;
        Ok(Executable { exe, path: p.display().to_string() })
    }
}

impl Executable {
    /// Execute with a single f32 input tensor; returns all tuple outputs as
    /// flat f32 vectors (integer outputs are converted).
    pub fn run_f32(&self, input: &[f32], shape: &[usize]) -> Result<Vec<Vec<f32>>> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims).context("reshaping input")?;
        let result = self.exe.execute::<xla::Literal>(&[lit]).context("executing")?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        // python lowered with return_tuple=True
        let tuple = result.to_tuple().context("decomposing tuple")?;
        let mut out = Vec::with_capacity(tuple.len());
        for t in tuple {
            match t.ty() {
                Ok(xla::ElementType::F32) => out.push(t.to_vec::<f32>().context("f32 out")?),
                Ok(xla::ElementType::S32) => out.push(
                    t.to_vec::<i32>().context("i32 out")?.into_iter().map(|v| v as f32).collect(),
                ),
                other => anyhow::bail!("unsupported output element type {other:?}"),
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    // PJRT tests live in rust/tests/runtime_pjrt.rs (they need artifacts
    // and take ~seconds to compile HLO; keeping them out of `--lib`).
}
