//! PJRT runtime: load AOT-compiled HLO text (from `python/compile/aot.py`)
//! and execute it on the CPU PJRT client via the `xla` crate.
//!
//! This is the fast path of the stack: the same quantized computation the
//! bit-accurate engine interprets is also available as a fused XLA
//! executable built around the Layer-1 Pallas kernel
//! (`artifacts/model.hlo.txt` — sorted1 policy, 16-bit accumulator), plus
//! FP32 baselines under `artifacts/hlo/`.
//!
//! Interchange is HLO *text*: jax >= 0.5 emits protos with 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids
//! (see /opt/xla-example/README.md).
//!
//! ### Offline builds
//!
//! The `xla` crate is unavailable in the offline build environment, so the
//! real implementation is gated behind the `pjrt` cargo feature (which also
//! requires re-adding the `xla` dependency). The default build exposes the
//! same API as a stub whose constructors return a descriptive error, so
//! callers degrade gracefully (`examples/serve.rs` skips the HLO
//! cross-check, `rust/tests/runtime_pjrt.rs` skips, the `pqs runtime`
//! subcommand reports the missing feature).

#[cfg(feature = "pjrt")]
mod imp {
    use anyhow::{Context, Result};
    use std::path::Path;

    /// A PJRT CPU client + compiled executables.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    /// One compiled HLO program with a fixed input batch size.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub path: String,
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO text file.
        pub fn load_hlo<P: AsRef<Path>>(&self, path: P) -> Result<Executable> {
            let p = path.as_ref();
            let proto = xla::HloModuleProto::from_text_file(p)
                .with_context(|| format!("parsing HLO text {p:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compiling {p:?}"))?;
            Ok(Executable { exe, path: p.display().to_string() })
        }
    }

    impl Executable {
        /// Execute with a single f32 input tensor; returns all tuple outputs
        /// as flat f32 vectors (integer outputs are converted).
        pub fn run_f32(&self, input: &[f32], shape: &[usize]) -> Result<Vec<Vec<f32>>> {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(input).reshape(&dims).context("reshaping input")?;
            let result = self.exe.execute::<xla::Literal>(&[lit]).context("executing")?[0][0]
                .to_literal_sync()
                .context("fetching result")?;
            // python lowered with return_tuple=True
            let tuple = result.to_tuple().context("decomposing tuple")?;
            let mut out = Vec::with_capacity(tuple.len());
            for t in tuple {
                match t.ty() {
                    Ok(xla::ElementType::F32) => out.push(t.to_vec::<f32>().context("f32 out")?),
                    Ok(xla::ElementType::S32) => out.push(
                        t.to_vec::<i32>()
                            .context("i32 out")?
                            .into_iter()
                            .map(|v| v as f32)
                            .collect(),
                    ),
                    other => anyhow::bail!("unsupported output element type {other:?}"),
                }
            }
            Ok(out)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use anyhow::{bail, Result};
    use std::path::Path;

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: pqs was built without the `pjrt` feature \
         (the `xla` crate is not present in this offline environment)";

    /// Stub PJRT runtime (built without the `pjrt` feature).
    pub struct Runtime {
        _private: (),
    }

    /// Stub compiled executable (built without the `pjrt` feature).
    pub struct Executable {
        pub path: String,
    }

    impl Runtime {
        /// Always fails in stub builds; use [`Runtime::available`] to probe.
        pub fn cpu() -> Result<Runtime> {
            bail!("{UNAVAILABLE}")
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load_hlo<P: AsRef<Path>>(&self, _path: P) -> Result<Executable> {
            bail!("{UNAVAILABLE}")
        }
    }

    impl Executable {
        pub fn run_f32(&self, _input: &[f32], _shape: &[usize]) -> Result<Vec<Vec<f32>>> {
            bail!("{UNAVAILABLE}")
        }
    }
}

pub use imp::{Executable, Runtime};

impl Runtime {
    /// Whether this build carries a real PJRT backend. Callers that merely
    /// *demonstrate* the HLO path (examples, integration tests) should probe
    /// this and skip gracefully instead of failing.
    pub fn available() -> bool {
        cfg!(feature = "pjrt")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT tests against real artifacts live in rust/tests/runtime_pjrt.rs
    // (they need artifacts and take ~seconds to compile HLO; keeping them
    // out of `--lib`).

    #[test]
    fn stub_reports_unavailable() {
        if !Runtime::available() {
            let err = Runtime::cpu().err().expect("stub must error");
            let msg = format!("{err:#}");
            assert!(msg.contains("pjrt"), "unhelpful error: {msg}");
        }
    }
}
