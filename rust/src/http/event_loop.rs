//! Readiness-driven connection backend: one thread multiplexing every
//! socket over `epoll`.
//!
//! ## Architecture
//!
//! ```text
//!            epoll_wait ── readiness ──┐
//!   accept ──► slab slot (Conn state machine: buf ─ parser ─ out)
//!                │   fast GET/HEAD: answered inline on the loop
//!                │   POST /v1/classify: prepare inline, then
//!                ▼
//!        WorkerPool<Job> (blocking router submit + wait)
//!                │
//!        Completions queue ── self-pipe wake ──► loop writes response
//! ```
//!
//! * **Vendored shim, no tokio**: the `sys` module declares the five
//!   syscalls we need (`epoll_create1`/`epoll_ctl`/`epoll_wait`/`pipe`
//!   plus `read`/`write`/`close`) as `extern "C"` into libc, which the
//!   std runtime already links. Level-triggered mode everywhere.
//! * **Per-connection state machine**: nonblocking reads append to
//!   `Conn::buf`; the incremental parser is a pure function of that
//!   buffered prefix, so it drops in unchanged. Encoded responses land
//!   in `Conn::out`; a short write registers `EPOLLOUT` interest and the
//!   remainder flushes when the socket drains.
//! * **One in-flight classify per connection**: read interest is dropped
//!   while a request is with the workers (the kernel socket buffer is
//!   the backpressure), which trivially preserves pipelined response
//!   ordering and mid-pipeline `Connection: close` semantics.
//! * **Timer wheel** (512 slots × 16 ms): keep-alive idling, the
//!   anti-slowloris partial-request hard cap, and the in-flight backstop
//!   all collapse onto one deadline per connection, re-armed at state
//!   transitions. Lazy deletion: each re-arm bumps `timer_seq`, stale
//!   wheel entries no-op when they fire. Entries past the horizon clamp
//!   to the last slot and cascade by re-scheduling.
//! * **Self-pipe wakeups**: workers enqueue completions into a mutexed
//!   vector and write one byte into a plain `pipe()` at most once per
//!   drain cycle (a `wake_armed` flag bounds it), so the blocking pipe
//!   ends can never fill and deadlock.
//!
//! The loop sustains tens of thousands of idle keep-alive connections
//! with exactly `1 + conn_threads` threads; [`super::HttpConfig::max_connections`]
//! bounds the slab, accepts past it shed with 503.

use std::io::{Read, Write};
use std::net::TcpListener;
use std::os::raw::c_int;
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::ClassifyRequest;
use crate::util::pool::WorkerPool;

use super::parser::{self, Version};
use super::server::{
    encode_reply, prepare_classify, route_fast, run_classify, shed_connection, Ctx, Reply,
    SHED_MAX_CONNECTIONS, SHED_QUEUE_FULL,
};

// ---- raw epoll / pipe shim ------------------------------------------------

mod sys {
    use std::os::raw::c_int;

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;

    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;

    /// Kernel ABI: packed on x86-64 (12 bytes), naturally aligned
    /// (16 bytes) everywhere else. Read fields by value only — taking a
    /// reference into a packed struct is undefined behavior.
    #[derive(Clone, Copy)]
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        pub fn pipe(fds: *mut c_int) -> c_int;
        pub fn read(fd: c_int, buf: *mut u8, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const u8, count: usize) -> isize;
        pub fn close(fd: c_int) -> c_int;
    }
}

use sys::{EpollEvent, EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT};

struct Epoll {
    fd: RawFd,
}

impl Epoll {
    fn new() -> std::io::Result<Epoll> {
        let fd = unsafe { sys::epoll_create1(0) };
        if fd < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(Epoll { fd })
    }

    fn ctl(&self, op: c_int, fd: RawFd, events: u32, token: u64) -> std::io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        let arg = if op == sys::EPOLL_CTL_DEL { std::ptr::null_mut() } else { &mut ev };
        if unsafe { sys::epoll_ctl(self.fd, op, fd, arg) } < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(())
    }

    fn add(&self, fd: RawFd, events: u32, token: u64) -> std::io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, events, token)
    }

    fn modify(&self, fd: RawFd, events: u32, token: u64) -> std::io::Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, events, token)
    }

    fn del(&self, fd: RawFd) {
        let _ = self.ctl(sys::EPOLL_CTL_DEL, fd, 0, 0);
    }

    /// `None` blocks indefinitely. EINTR reports as zero events.
    fn wait(&self, events: &mut [EpollEvent], timeout: Option<Duration>) -> usize {
        let millis: c_int = match timeout {
            None => -1,
            Some(d) => {
                // round up so we never wake before the deadline and spin
                let ms = d.as_millis().saturating_add(u128::from(d.subsec_nanos() % 1_000_000 > 0));
                ms.min(c_int::MAX as u128) as c_int
            }
        };
        let n = unsafe {
            sys::epoll_wait(self.fd, events.as_mut_ptr(), events.len() as c_int, millis)
        };
        if n < 0 {
            let e = std::io::Error::last_os_error();
            if e.kind() != std::io::ErrorKind::Interrupted {
                // nothing sane to do from the loop; surface and carry on
                eprintln!("epoll_wait failed: {e}");
            }
            return 0;
        }
        n as usize
    }
}

impl Drop for Epoll {
    fn drop(&mut self) {
        unsafe { sys::close(self.fd) };
    }
}

/// Write end of the loop's self-pipe. Cloned into worker completions and
/// held by [`super::HttpServer`] for shutdown.
pub(crate) struct Waker {
    fd: RawFd,
}

impl Waker {
    pub(crate) fn wake(&self) {
        let byte = 1u8;
        let _ = unsafe { sys::write(self.fd, &byte, 1) };
    }
}

impl Drop for Waker {
    fn drop(&mut self) {
        unsafe { sys::close(self.fd) };
    }
}

// ---- worker completions ---------------------------------------------------

struct Done {
    idx: usize,
    gen: u64,
    reply: Reply,
}

struct CompletionState {
    done: Vec<Done>,
    /// a wake byte is already in the pipe; don't write another until the
    /// loop drains — this bounds the pipe to one outstanding byte per
    /// cycle so the blocking ends can never fill
    wake_armed: bool,
}

struct Completions {
    state: Mutex<CompletionState>,
    waker: Arc<Waker>,
}

impl Completions {
    fn push(&self, d: Done) {
        let mut s = self.state.lock().unwrap();
        s.done.push(d);
        if !s.wake_armed {
            s.wake_armed = true;
            self.waker.wake();
        }
    }

    fn take(&self) -> Vec<Done> {
        let mut s = self.state.lock().unwrap();
        s.wake_armed = false;
        std::mem::take(&mut s.done)
    }
}

// ---- timer wheel ----------------------------------------------------------

const WHEEL_SLOTS: usize = 512;
const WHEEL_TICK_MS: u64 = 16;

/// Hashed timing wheel: 512 slots × 16 ms ≈ an 8 s horizon. Deadlines
/// past the horizon clamp to the far edge and cascade (the driver
/// re-schedules any entry whose real deadline hasn't passed when it
/// fires). Deletion is lazy — the driver drops entries whose `seq` no
/// longer matches the connection's live `timer_seq`.
struct TimerWheel {
    slots: Vec<Vec<(usize, u64)>>,
    /// next tick to drain (everything below has been expired)
    cursor: u64,
    start: Instant,
    scheduled: usize,
}

impl TimerWheel {
    fn new(start: Instant) -> TimerWheel {
        TimerWheel { slots: vec![Vec::new(); WHEEL_SLOTS], cursor: 0, start, scheduled: 0 }
    }

    fn tick_of(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.start).as_millis() as u64 / WHEEL_TICK_MS
    }

    fn schedule(&mut self, idx: usize, seq: u64, deadline: Instant) {
        let tick = self.tick_of(deadline).clamp(self.cursor, self.cursor + WHEEL_SLOTS as u64 - 1);
        self.slots[(tick % WHEEL_SLOTS as u64) as usize].push((idx, seq));
        self.scheduled += 1;
    }

    /// Drain every slot whose tick has passed and return the fired
    /// entries. Advances the cursor *before* the caller re-schedules, so
    /// cascading entries land at future ticks instead of spinning.
    fn expire(&mut self, now: Instant) -> Vec<(usize, u64)> {
        let current = self.tick_of(now);
        if current < self.cursor || self.scheduled == 0 {
            // keep the cursor abreast of time even while empty, so a new
            // entry is never clamped onto a long-passed tick
            self.cursor = self.cursor.max(current);
            return Vec::new();
        }
        let span = (current - self.cursor + 1).min(WHEEL_SLOTS as u64);
        let from = self.cursor;
        self.cursor = current + 1;
        let mut fired = Vec::new();
        for i in 0..span {
            let slot = ((from + i) % WHEEL_SLOTS as u64) as usize;
            if !self.slots[slot].is_empty() {
                self.scheduled -= self.slots[slot].len();
                fired.append(&mut self.slots[slot]);
            }
        }
        fired
    }

    /// How long `epoll_wait` may sleep: until just past the first
    /// non-empty slot's tick boundary, or forever when nothing is armed.
    fn next_timeout(&self, now: Instant) -> Option<Duration> {
        if self.scheduled == 0 {
            return None;
        }
        for i in 0..WHEEL_SLOTS as u64 {
            let tick = self.cursor + i;
            if !self.slots[(tick % WHEEL_SLOTS as u64) as usize].is_empty() {
                let boundary =
                    self.start + Duration::from_millis((tick + 1) * WHEEL_TICK_MS);
                return Some(boundary.saturating_duration_since(now));
            }
        }
        Some(Duration::from_millis(WHEEL_TICK_MS))
    }
}

// ---- the driver -----------------------------------------------------------

const TOKEN_LISTENER: u64 = u64::MAX;
const TOKEN_WAKER: u64 = u64::MAX - 1;
/// fairness cap: max reads per readiness event before yielding back to
/// the loop (level-triggered epoll re-delivers whatever is left)
const READS_PER_EVENT: usize = 16;

struct Conn {
    stream: std::net::TcpStream,
    /// guards stale classify completions after slot reuse
    gen: u64,
    buf: Vec<u8>,
    out: Vec<u8>,
    written: usize,
    inflight: bool,
    close_after_flush: bool,
    peer_closed: bool,
    /// epoll events currently registered for this fd
    interest: u32,
    deadline: Instant,
    timer_seq: u64,
    /// a partial request is on the clock: answer 408 on expiry instead
    /// of closing silently
    timeout_408: bool,
}

struct Job {
    idx: usize,
    gen: u64,
    request: ClassifyRequest,
    keep: bool,
    http11: bool,
}

enum Step {
    Incomplete,
    Reply(Reply, usize),
    Dispatch(Box<ClassifyRequest>, bool, bool, usize),
    Fatal(Reply),
}

struct Driver {
    ctx: Arc<Ctx>,
    epoll: Epoll,
    listener: Option<TcpListener>,
    slots: Vec<Option<Conn>>,
    free: Vec<usize>,
    /// slots closed during the current event batch; recycled only at the
    /// top of the next iteration so a stale readiness event in this
    /// batch can never alias a freshly accepted connection
    dying: Vec<usize>,
    live: usize,
    wheel: TimerWheel,
    next_seq: u64,
    next_gen: u64,
    pool: Option<WorkerPool<Job>>,
    completions: Arc<Completions>,
    pipe_read: RawFd,
    accept_err_reported: bool,
    draining: bool,
    drain_deadline: Instant,
    scratch: [u8; 8192],
}

/// Start the event-loop backend: returns the loop thread and the waker
/// that interrupts its `epoll_wait` (used by shutdown and by classify
/// workers delivering completions).
pub(crate) fn spawn(
    ctx: Arc<Ctx>,
    listener: TcpListener,
) -> std::io::Result<(JoinHandle<()>, Arc<Waker>)> {
    let epoll = Epoll::new()?;
    let mut fds = [0 as c_int; 2];
    if unsafe { sys::pipe(fds.as_mut_ptr()) } < 0 {
        return Err(std::io::Error::last_os_error());
    }
    let (pipe_read, pipe_write) = (fds[0], fds[1]);
    let waker = Arc::new(Waker { fd: pipe_write });

    epoll.add(listener.as_raw_fd(), EPOLLIN, TOKEN_LISTENER)?;
    epoll.add(pipe_read, EPOLLIN, TOKEN_WAKER)?;

    let completions = Arc::new(Completions {
        state: Mutex::new(CompletionState { done: Vec::new(), wake_armed: false }),
        waker: Arc::clone(&waker),
    });

    let wctx = Arc::clone(&ctx);
    let wdone = Arc::clone(&completions);
    let cfg = ctx.cfg;
    let pool = WorkerPool::new(
        cfg.conn_threads.max(1),
        cfg.conn_backlog.max(1),
        move |job: Job| {
            let reply = run_classify(&wctx, job.request, job.keep, job.http11);
            wdone.push(Done { idx: job.idx, gen: job.gen, reply });
        },
    );

    let now = Instant::now();
    let mut driver = Driver {
        ctx,
        epoll,
        listener: Some(listener),
        slots: Vec::new(),
        free: Vec::new(),
        dying: Vec::new(),
        live: 0,
        wheel: TimerWheel::new(now),
        next_seq: 0,
        next_gen: 0,
        pool: Some(pool),
        completions,
        pipe_read,
        accept_err_reported: false,
        draining: false,
        drain_deadline: now,
        scratch: [0u8; 8192],
    };
    let handle = std::thread::Builder::new()
        .name("http-event-loop".into())
        .spawn(move || driver.run())?;
    Ok((handle, waker))
}

impl Driver {
    fn run(&mut self) {
        let mut events = vec![EpollEvent { events: 0, data: 0 }; 1024];
        loop {
            self.free.append(&mut self.dying);
            let now = Instant::now();
            if self.ctx.stop.load(Ordering::Acquire) && !self.draining {
                self.begin_drain(now);
            }
            if self.draining && (self.live == 0 || now >= self.drain_deadline) {
                break;
            }
            let timeout = if self.draining {
                // poll the drain exit condition even if no fd fires
                Some(self.wheel.next_timeout(now).unwrap_or(Duration::from_millis(50)).min(
                    Duration::from_millis(50),
                ))
            } else {
                self.wheel.next_timeout(now)
            };
            let n = self.epoll.wait(&mut events, timeout);
            let now = Instant::now();
            for ev in &events[..n] {
                let token = ev.data; // value copy: the struct may be packed
                let flags = ev.events;
                match token {
                    TOKEN_WAKER => {
                        let mut buf = [0u8; 64];
                        let _ = unsafe { sys::read(self.pipe_read, buf.as_mut_ptr(), buf.len()) };
                    }
                    TOKEN_LISTENER => self.accept_ready(now),
                    _ => {
                        let idx = token as usize;
                        if idx >= self.slots.len() || self.slots[idx].is_none() {
                            continue; // closed earlier in this batch
                        }
                        if flags & (EPOLLERR | EPOLLHUP) != 0 {
                            self.close(idx);
                            continue;
                        }
                        if flags & EPOLLIN != 0 {
                            self.on_readable(idx, now);
                        }
                        if flags & EPOLLOUT != 0 && self.slots[idx].is_some() {
                            self.finish_io(idx, now);
                        }
                    }
                }
            }
            for d in self.completions.take() {
                self.complete(d, now);
            }
            for (idx, seq) in self.wheel.expire(now) {
                self.on_timer(idx, seq, now);
            }
        }
        // drain grace over (or everything closed): tear down
        if let Some(l) = self.listener.take() {
            self.epoll.del(l.as_raw_fd());
        }
        let open: Vec<usize> =
            (0..self.slots.len()).filter(|&i| self.slots[i].is_some()).collect();
        for idx in open {
            self.close(idx);
        }
        if let Some(pool) = self.pool.take() {
            // joins the classify workers, which drops their Arc<Ctx>
            // clones so HttpServer::shutdown can unwrap the context
            pool.shutdown();
        }
        unsafe { sys::close(self.pipe_read) };
    }

    fn begin_drain(&mut self, now: Instant) {
        self.draining = true;
        self.drain_deadline = now + self.ctx.cfg.response_timeout + Duration::from_secs(1);
        if let Some(l) = self.listener.take() {
            self.epoll.del(l.as_raw_fd());
        }
        let idxs: Vec<usize> =
            (0..self.slots.len()).filter(|&i| self.slots[i].is_some()).collect();
        for idx in idxs {
            let (idle, flushed) = {
                let c = self.slots[idx].as_ref().unwrap();
                (!c.inflight, c.out.len() == c.written)
            };
            if idle && flushed {
                self.close(idx);
            } else if let Some(c) = &mut self.slots[idx] {
                // flush what's pending (and any in-flight answer), then go
                c.close_after_flush = true;
            }
        }
    }

    // -- accept path --

    fn accept_ready(&mut self, now: Instant) {
        // taken out for the duration so `install` can borrow self freely
        let listener = match self.listener.take() {
            Some(l) => l,
            None => return,
        };
        loop {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    // injected connection reset: drop before reading a
                    // byte, like a peer RST between accept and first read
                    // (counted by the fault plan, not in `accepted`)
                    if let Some(f) = self.ctx.router.faults() {
                        if f.reset_accept() {
                            drop(stream);
                            continue;
                        }
                    }
                    self.ctx.http.accepted.fetch_add(1, Ordering::Relaxed);
                    if self.live >= self.ctx.cfg.max_connections {
                        self.ctx.http.accepted.fetch_sub(1, Ordering::Relaxed);
                        self.ctx.http.count_shed(SHED_MAX_CONNECTIONS);
                        self.ctx.tracer.record_shed(SHED_MAX_CONNECTIONS);
                        shed_connection(stream);
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        self.ctx.http.accepted.fetch_sub(1, Ordering::Relaxed);
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    self.install(stream, now);
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    // e.g. fd exhaustion: report once, back off briefly so a
                    // level-triggered pending connection can't spin the loop
                    if !self.accept_err_reported {
                        self.accept_err_reported = true;
                        eprintln!("http accept error (backing off): {e}");
                    }
                    std::thread::sleep(Duration::from_millis(10));
                    break;
                }
            }
        }
        self.listener = Some(listener);
    }

    fn install(&mut self, stream: std::net::TcpStream, now: Instant) {
        let idx = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(None);
                self.slots.len() - 1
            }
        };
        self.next_gen += 1;
        let fd = stream.as_raw_fd();
        let conn = Conn {
            stream,
            gen: self.next_gen,
            buf: Vec::new(),
            out: Vec::new(),
            written: 0,
            inflight: false,
            close_after_flush: false,
            peer_closed: false,
            interest: EPOLLIN,
            deadline: now,
            timer_seq: 0,
            timeout_408: false,
        };
        if self.epoll.add(fd, EPOLLIN, idx as u64).is_err() {
            self.free.push(idx);
            self.ctx.http.accepted.fetch_sub(1, Ordering::Relaxed);
            return;
        }
        self.slots[idx] = Some(conn);
        self.live += 1;
        let ka = self.ctx.cfg.keep_alive_timeout;
        self.arm(idx, now + ka, false);
    }

    // -- timers --

    fn arm(&mut self, idx: usize, deadline: Instant, timeout_408: bool) {
        self.next_seq += 1;
        let seq = self.next_seq;
        if let Some(c) = &mut self.slots[idx] {
            c.deadline = deadline;
            c.timer_seq = seq;
            c.timeout_408 = timeout_408;
            self.wheel.schedule(idx, seq, deadline);
        }
    }

    fn on_timer(&mut self, idx: usize, seq: u64, now: Instant) {
        let not_due_yet = match self.slots.get(idx).and_then(Option::as_ref) {
            Some(c) if c.timer_seq == seq => (c.deadline > now).then_some(c.deadline),
            _ => return, // slot reused or re-armed since: stale entry
        };
        if let Some(deadline) = not_due_yet {
            // cascaded (past-horizon) or slot-aliased entry that fired
            // early: push it back out toward its real deadline
            self.wheel.schedule(idx, seq, deadline);
            return;
        }
        let answer_408 = {
            let c = self.slots[idx].as_ref().unwrap();
            !c.inflight && !c.close_after_flush && c.timeout_408
        };
        if answer_408 {
            self.ctx.http.read_timeouts.fetch_add(1, Ordering::Relaxed);
            self.enqueue_reply(idx, Reply::error(408, "request incomplete", false), now);
            self.finish_io(idx, now);
        } else {
            // idle expiry, a stuck in-flight backstop, or a peer too slow
            // to read its response: nothing useful left to say
            self.close(idx);
        }
    }

    // -- I/O state machine --

    fn on_readable(&mut self, idx: usize, now: Instant) {
        let mut fatal = false;
        let mut was_empty = false;
        let mut grew = false;
        if let Some(c) = &mut self.slots[idx] {
            if c.inflight || c.close_after_flush {
                // level-triggered race after interest change: ignore
                self.finish_io(idx, now);
                return;
            }
            was_empty = c.buf.is_empty();
            for _ in 0..READS_PER_EVENT {
                match c.stream.read(&mut self.scratch) {
                    Ok(0) => {
                        c.peer_closed = true;
                        break;
                    }
                    Ok(n) => {
                        c.buf.extend_from_slice(&self.scratch[..n]);
                        grew = true;
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(_) => {
                        fatal = true;
                        break;
                    }
                }
            }
        } else {
            return;
        }
        if fatal {
            self.close(idx);
            return;
        }
        if was_empty && grew {
            // first byte of a request: the whole head+body must arrive
            // within the keep-alive budget (hard cap, never extended on
            // read progress — a one-byte-per-tick drip can't hold a slot)
            let ka = self.ctx.cfg.keep_alive_timeout;
            self.arm(idx, now + ka, true);
        }
        self.advance(idx, now);
        self.finish_io(idx, now);
    }

    /// Parse and answer every complete buffered request until the buffer
    /// runs dry, a classify goes in flight, or the connection is closing.
    fn advance(&mut self, idx: usize, now: Instant) {
        loop {
            let step = {
                let stopping = self.draining || self.ctx.stop.load(Ordering::Acquire);
                let c = match &mut self.slots[idx] {
                    Some(c) => c,
                    None => return,
                };
                if c.inflight || c.close_after_flush {
                    return;
                }
                match parser::parse_request(&c.buf, &self.ctx.cfg.limits) {
                    Ok(None) => Step::Incomplete,
                    Err(e) => Step::Fatal(Reply::error(e.status(), e.message(), false)),
                    Ok(Some((req, consumed))) => {
                        let keep = req.keep_alive() && !stopping;
                        let http11 = req.version == Version::Http11;
                        match route_fast(&self.ctx, &req) {
                            Some(reply) => Step::Reply(reply, consumed),
                            None => match prepare_classify(&self.ctx, &req, keep, now) {
                                Ok(request) => {
                                    Step::Dispatch(Box::new(request), keep, http11, consumed)
                                }
                                Err(reply) => Step::Reply(reply, consumed),
                            },
                        }
                    }
                }
            };
            match step {
                Step::Incomplete => return,
                Step::Fatal(reply) => {
                    self.enqueue_reply(idx, reply, now);
                    return;
                }
                Step::Reply(reply, consumed) => {
                    if let Some(c) = &mut self.slots[idx] {
                        c.buf.drain(..consumed);
                    }
                    self.enqueue_reply(idx, reply, now);
                    // keep going: more pipelined requests may be buffered
                }
                Step::Dispatch(request, keep, http11, consumed) => {
                    let gen = {
                        let c = self.slots[idx].as_mut().unwrap();
                        c.buf.drain(..consumed);
                        c.inflight = true;
                        c.gen
                    };
                    let job = Job { idx, gen, request: *request, keep, http11 };
                    let pool = self.pool.as_ref().expect("pool lives for the loop's life");
                    if let Err(job) = pool.try_dispatch(job) {
                        // classify backlog full: answer inline, keep the
                        // connection (the condition is transient)
                        if let Some(c) = &mut self.slots[idx] {
                            c.inflight = false;
                        }
                        self.ctx.http.count_shed(SHED_QUEUE_FULL);
                        self.ctx.tracer.record_shed(SHED_QUEUE_FULL);
                        let mut reply = Reply::retryable(503, "server busy", job.keep, 1);
                        reply.http11 = job.http11;
                        self.enqueue_reply(idx, reply, now);
                    } else {
                        // backstop only: the router's own deadline/timeout
                        // machinery answers long before this fires
                        let cap = self.ctx.cfg.response_timeout + self.ctx.cfg.keep_alive_timeout;
                        self.arm(idx, now + cap, false);
                        return;
                    }
                }
            }
        }
    }

    /// A worker finished a classify for slot `idx` (if the connection is
    /// still the same generation and still waiting).
    fn complete(&mut self, d: Done, now: Instant) {
        let valid = matches!(
            self.slots.get(d.idx).and_then(Option::as_ref),
            Some(c) if c.gen == d.gen && c.inflight
        );
        if !valid {
            return; // connection closed or slot reused while in flight
        }
        self.slots[d.idx].as_mut().unwrap().inflight = false;
        self.enqueue_reply(d.idx, d.reply, now);
        self.advance(d.idx, now); // pipelined follow-ups may be buffered
        self.finish_io(d.idx, now);
    }

    /// Encode one response onto the connection's write buffer and re-arm
    /// its deadline.
    fn enqueue_reply(&mut self, idx: usize, reply: Reply, now: Instant) {
        let threshold = self.ctx.cfg.stream_threshold;
        let draining = self.draining;
        let ka = self.ctx.cfg.keep_alive_timeout;
        let (deadline, t408) = {
            let c = match &mut self.slots[idx] {
                Some(c) => c,
                None => return,
            };
            let bytes = encode_reply(&reply, threshold);
            c.out.extend_from_slice(&bytes);
            if !reply.keep || draining {
                c.close_after_flush = true;
            }
            if c.close_after_flush {
                // flush deadline: close even if the peer won't read
                (now + ka, false)
            } else if c.buf.is_empty() {
                (now + ka, false) // plain keep-alive idle
            } else {
                (now + ka, true) // partial pipelined request on the clock
            }
        };
        self.arm(idx, deadline, t408);
    }

    /// Flush pending output, settle epoll interest, close if terminal.
    fn finish_io(&mut self, idx: usize, _now: Instant) {
        let mut fatal = false;
        let mut desired = 0u32;
        let mut should_close = false;
        if let Some(c) = &mut self.slots[idx] {
            while c.written < c.out.len() {
                match c.stream.write(&c.out[c.written..]) {
                    Ok(0) => {
                        fatal = true;
                        break;
                    }
                    Ok(n) => c.written += n,
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(_) => {
                        // EPIPE and friends (std ignores SIGPIPE)
                        fatal = true;
                        break;
                    }
                }
            }
            if c.written == c.out.len() && !c.out.is_empty() {
                c.out.clear();
                c.written = 0;
            }
            let flushed = c.out.is_empty();
            should_close = !fatal
                && flushed
                && (c.close_after_flush || (c.peer_closed && !c.inflight));
            if !fatal && !should_close {
                if !c.inflight && !c.close_after_flush && !c.peer_closed {
                    desired |= EPOLLIN;
                }
                if !flushed {
                    desired |= EPOLLOUT;
                }
                if desired != c.interest {
                    let fd = c.stream.as_raw_fd();
                    let token = idx as u64;
                    if self.epoll.modify(fd, desired, token).is_err() {
                        fatal = true;
                    } else {
                        c.interest = desired;
                    }
                }
            }
        } else {
            return;
        }
        if fatal || should_close {
            self.close(idx);
        }
    }

    fn close(&mut self, idx: usize) {
        if let Some(c) = self.slots[idx].take() {
            self.epoll.del(c.stream.as_raw_fd());
            drop(c.stream);
            self.live -= 1;
            self.dying.push(idx);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg(target_arch = "x86_64")]
    fn epoll_event_matches_kernel_abi() {
        // x86-64 packs epoll_event to 12 bytes; anything else corrupts
        // the event array the kernel writes into
        assert_eq!(std::mem::size_of::<EpollEvent>(), 12);
    }

    #[test]
    fn waker_interrupts_epoll_wait() {
        let epoll = Epoll::new().expect("epoll_create1");
        let mut fds = [0 as c_int; 2];
        assert!(unsafe { sys::pipe(fds.as_mut_ptr()) } >= 0);
        let waker = Waker { fd: fds[1] };
        epoll.add(fds[0], EPOLLIN, TOKEN_WAKER).expect("add pipe");
        waker.wake();
        let mut events = [EpollEvent { events: 0, data: 0 }; 4];
        let n = epoll.wait(&mut events, Some(Duration::from_secs(5)));
        assert_eq!(n, 1);
        let token = events[0].data;
        assert_eq!(token, TOKEN_WAKER);
        unsafe { sys::close(fds[0]) };
    }

    #[test]
    fn wheel_fires_due_entries_in_order() {
        let start = Instant::now();
        let mut w = TimerWheel::new(start);
        w.schedule(1, 10, start + Duration::from_millis(20));
        w.schedule(2, 11, start + Duration::from_millis(200));
        let fired = w.expire(start + Duration::from_millis(40));
        assert_eq!(fired, vec![(1, 10)]);
        let fired = w.expire(start + Duration::from_millis(40));
        assert!(fired.is_empty(), "cursor advanced; nothing re-fires");
        let fired = w.expire(start + Duration::from_millis(250));
        assert_eq!(fired, vec![(2, 11)]);
        assert_eq!(w.scheduled, 0);
    }

    #[test]
    fn wheel_clamps_far_deadlines_to_horizon() {
        let start = Instant::now();
        let mut w = TimerWheel::new(start);
        // deadline far past the 512-slot horizon: entry must land inside
        // the wheel and fire (early), letting the driver cascade it
        w.schedule(7, 1, start + Duration::from_secs(3600));
        let horizon = Duration::from_millis(WHEEL_TICK_MS * WHEEL_SLOTS as u64 + 100);
        let fired = w.expire(start + horizon);
        assert_eq!(fired, vec![(7, 1)]);
    }

    #[test]
    fn wheel_next_timeout_tracks_first_entry() {
        let start = Instant::now();
        let mut w = TimerWheel::new(start);
        assert!(w.next_timeout(start).is_none(), "empty wheel sleeps forever");
        w.schedule(3, 5, start + Duration::from_millis(100));
        let t = w.next_timeout(start).expect("armed");
        // wakes at the covering tick's far boundary: due <= wake <= due + tick
        assert!(t >= Duration::from_millis(100), "woke before the deadline: {t:?}");
        assert!(t <= Duration::from_millis(100 + WHEEL_TICK_MS), "overslept: {t:?}");
    }

    #[test]
    fn wheel_lazy_deletion_leaves_stale_seqs_to_caller() {
        let start = Instant::now();
        let mut w = TimerWheel::new(start);
        w.schedule(4, 1, start + Duration::from_millis(16));
        w.schedule(4, 2, start + Duration::from_millis(32)); // re-arm, new seq
        let fired = w.expire(start + Duration::from_millis(64));
        // both entries fire; the driver drops seq 1 as stale
        assert_eq!(fired.len(), 2);
        assert!(fired.contains(&(4, 1)) && fired.contains(&(4, 2)));
    }
}
