//! Incremental, zero-copy HTTP/1.1 request parser.
//!
//! [`parse_request`] is a pure function of the connection buffer's current
//! prefix: it returns `Ok(None)` until one complete request (head + body)
//! is buffered, then a [`Request`] whose `&str`/`&[u8]` fields *borrow*
//! the buffer — no allocation beyond the header index vector, no copying
//! of the body. Because the decision is recomputed from the prefix, the
//! parse result is identical no matter how the bytes were split across
//! `read()` boundaries (the chunking property test in `rust/tests/http.rs`
//! and the exhaustive prefix test below both pin this down).
//!
//! Strictness follows RFC 9112 where it prevents request smuggling:
//! whitespace before the header colon, obsolete line folding, conflicting
//! or non-numeric `Content-Length` values, and any `Transfer-Encoding`
//! other than exactly `chunked` are all rejected with a 400-class error.
//! Line endings are lenient: both CRLF and bare LF terminate lines.
//! Head/body size limits map to 413.
//!
//! `Transfer-Encoding: chunked` bodies are decoded in place: chunk sizes
//! (hex, optional `;extension` ignored), per-chunk CRLF framing, and a
//! trailer section validated with the same header-field rules as the head
//! then discarded. The *decoded* body honours `Limits::max_body`; a
//! request carrying both `Transfer-Encoding` and `Content-Length` is
//! rejected (the classic smuggling vector). A chunked body is the one
//! case where [`Request::body`] is owned rather than borrowed (the chunk
//! data is not contiguous in the connection buffer) — hence the `Cow`.

use std::borrow::Cow;

/// Limits enforced while parsing. Exceeding a size limit maps to
/// `413 Content Too Large`.
#[derive(Clone, Copy, Debug)]
pub struct Limits {
    /// max bytes of request line + headers, terminator included
    pub max_head: usize,
    /// max number of header fields
    pub max_headers: usize,
    /// max declared `Content-Length`
    pub max_body: usize,
}

impl Default for Limits {
    fn default() -> Self {
        Limits { max_head: 16 * 1024, max_headers: 64, max_body: 4 * 1024 * 1024 }
    }
}

/// Parse failure, carrying the HTTP status code it maps onto.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ParseError {
    /// 400 Bad Request
    Bad(&'static str),
    /// 413 Content Too Large
    TooLarge(&'static str),
}

impl ParseError {
    pub fn status(&self) -> u16 {
        match self {
            ParseError::Bad(_) => 400,
            ParseError::TooLarge(_) => 413,
        }
    }

    pub fn message(&self) -> &'static str {
        match self {
            ParseError::Bad(m) | ParseError::TooLarge(m) => m,
        }
    }
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}", self.status(), self.message())
    }
}

impl std::error::Error for ParseError {}

/// HTTP version from the request line.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Version {
    Http10,
    Http11,
}

/// One parsed request. Every field borrows the connection buffer
/// (zero-copy) except a chunked body, which is decoded into an owned
/// buffer; drop the request before draining consumed bytes.
#[derive(Debug)]
pub struct Request<'a> {
    pub method: &'a str,
    pub target: &'a str,
    pub version: Version,
    /// header fields in wire order, names *not* normalized — use
    /// [`Request::header`] for case-insensitive lookup
    pub headers: Vec<(&'a str, &'a str)>,
    /// `Borrowed` for `Content-Length` framing (zero-copy), `Owned` for a
    /// decoded chunked body
    pub body: std::borrow::Cow<'a, [u8]>,
}

impl<'a> Request<'a> {
    /// Case-insensitive header lookup (first occurrence).
    pub fn header(&self, name: &str) -> Option<&'a str> {
        self.headers.iter().find(|(k, _)| k.eq_ignore_ascii_case(name)).map(|&(_, v)| v)
    }

    /// Request target with any query string stripped.
    pub fn path(&self) -> &'a str {
        self.target.split('?').next().unwrap_or(self.target)
    }

    /// Connection persistence: HTTP/1.1 defaults to keep-alive unless
    /// `Connection: close`; HTTP/1.0 defaults to close unless
    /// `Connection: keep-alive`.
    pub fn keep_alive(&self) -> bool {
        let conn = self.header("connection").unwrap_or("");
        let has = |tok: &str| conn.split(',').any(|t| t.trim().eq_ignore_ascii_case(tok));
        match self.version {
            Version::Http11 => !has("close"),
            Version::Http10 => has("keep-alive"),
        }
    }
}

/// End of the head section: byte offset just past the blank line.
/// Accepts `\r\n\r\n`, `\n\n`, and mixed (`\n\r\n`) terminators.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    let mut i = 0;
    while i < buf.len() {
        if buf[i] == b'\n' {
            if buf.get(i + 1) == Some(&b'\n') {
                return Some(i + 2);
            }
            if buf.get(i + 1) == Some(&b'\r') && buf.get(i + 2) == Some(&b'\n') {
                return Some(i + 3);
            }
        }
        i += 1;
    }
    None
}

/// RFC 9110 `tchar`: the characters legal in tokens (methods, header names).
fn is_tchar(b: u8) -> bool {
    b.is_ascii_alphanumeric()
        || matches!(
            b,
            b'!' | b'#'
                | b'$'
                | b'%'
                | b'&'
                | b'\''
                | b'*'
                | b'+'
                | b'-'
                | b'.'
                | b'^'
                | b'_'
                | b'`'
                | b'|'
                | b'~'
        )
}

/// Try to parse one complete request from the front of `buf`.
///
/// * `Ok(None)` — the buffer does not yet hold a complete request; read
///   more bytes and call again (incremental parsing).
/// * `Ok(Some((request, consumed)))` — one request parsed; drain
///   `consumed` bytes once the borrow ends. Pipelined bytes after
///   `consumed` are untouched.
/// * `Err(_)` — the prefix can never become a valid request; answer with
///   the error's status and close the connection.
pub fn parse_request<'a>(
    buf: &'a [u8],
    limits: &Limits,
) -> Result<Option<(Request<'a>, usize)>, ParseError> {
    let head_end = match find_head_end(buf) {
        Some(e) => e,
        None => {
            if buf.len() > limits.max_head {
                return Err(ParseError::TooLarge("request head exceeds limit"));
            }
            return Ok(None);
        }
    };
    if head_end > limits.max_head {
        return Err(ParseError::TooLarge("request head exceeds limit"));
    }
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| ParseError::Bad("request head is not valid utf-8"))?;
    // split into lines, tolerating CRLF and bare LF; the terminating blank
    // line(s) become trailing empties — drop them
    let mut lines: Vec<&str> =
        head.split('\n').map(|l| l.strip_suffix('\r').unwrap_or(l)).collect();
    while lines.last().is_some_and(|l| l.is_empty()) {
        lines.pop();
    }
    if lines.is_empty() {
        return Err(ParseError::Bad("empty request line"));
    }

    // ---- request line ----------------------------------------------------
    let mut parts = lines[0].split(' ');
    let method = parts.next().unwrap_or("");
    let target = parts.next().ok_or(ParseError::Bad("malformed request line"))?;
    let version = parts.next().ok_or(ParseError::Bad("malformed request line"))?;
    if parts.next().is_some() {
        return Err(ParseError::Bad("malformed request line"));
    }
    if method.is_empty() || !method.bytes().all(is_tchar) {
        return Err(ParseError::Bad("invalid method token"));
    }
    if target.is_empty() || target.bytes().any(|b| b <= b' ' || b == 0x7f) {
        return Err(ParseError::Bad("invalid request target"));
    }
    let version = match version {
        "HTTP/1.1" => Version::Http11,
        "HTTP/1.0" => Version::Http10,
        _ => return Err(ParseError::Bad("unsupported http version")),
    };

    // ---- header fields ---------------------------------------------------
    let mut headers: Vec<(&str, &str)> = Vec::with_capacity(lines.len().saturating_sub(1));
    for line in &lines[1..] {
        if headers.len() >= limits.max_headers {
            return Err(ParseError::TooLarge("too many header fields"));
        }
        if line.starts_with(' ') || line.starts_with('\t') {
            return Err(ParseError::Bad("obsolete header line folding"));
        }
        let (name, value) =
            line.split_once(':').ok_or(ParseError::Bad("header line without ':'"))?;
        if name.is_empty() || !name.bytes().all(is_tchar) {
            // also rejects whitespace before the colon (smuggling vector)
            return Err(ParseError::Bad("invalid header name"));
        }
        let value = value.trim_matches(|c: char| c == ' ' || c == '\t');
        if value.bytes().any(|b| (b < 0x20 && b != b'\t') || b == 0x7f) {
            return Err(ParseError::Bad("invalid header value"));
        }
        headers.push((name, value));
    }

    // ---- body framing ----------------------------------------------------
    // the only transfer coding implemented is exactly `chunked`; anything
    // else (gzip, chained codings) is rejected — ignoring an unknown
    // coding instead of rejecting it would be a request-smuggling vector
    let mut chunked = false;
    for (k, v) in &headers {
        if !k.eq_ignore_ascii_case("transfer-encoding") {
            continue;
        }
        if chunked || !v.eq_ignore_ascii_case("chunked") {
            return Err(ParseError::Bad("unsupported transfer-encoding"));
        }
        chunked = true;
    }
    let mut content_length: Option<usize> = None;
    for (k, v) in &headers {
        if !k.eq_ignore_ascii_case("content-length") {
            continue;
        }
        if v.is_empty() || !v.bytes().all(|b| b.is_ascii_digit()) {
            return Err(ParseError::Bad("invalid content-length"));
        }
        let n: usize =
            v.parse().map_err(|_| ParseError::TooLarge("declared body exceeds limit"))?;
        match content_length {
            Some(prev) if prev != n => {
                return Err(ParseError::Bad("conflicting content-length values"))
            }
            _ => content_length = Some(n),
        }
    }
    if chunked {
        // both framings at once is the classic smuggling vector
        if content_length.is_some() {
            return Err(ParseError::Bad("transfer-encoding with content-length"));
        }
        return match parse_chunked_body(buf, head_end, limits)? {
            Some((body, total)) => Ok(Some((
                Request { method, target, version, headers, body: Cow::Owned(body) },
                total,
            ))),
            None => Ok(None),
        };
    }
    let content_length = content_length.unwrap_or(0);
    if content_length > limits.max_body {
        return Err(ParseError::TooLarge("declared body exceeds limit"));
    }
    let total = head_end + content_length;
    if buf.len() < total {
        return Ok(None);
    }
    let body = Cow::Borrowed(&buf[head_end..total]);
    Ok(Some((Request { method, target, version, headers, body }, total)))
}

/// Longest chunk-size line tolerated while waiting for its terminator
/// (16 hex digits + generous extension room); prevents an attacker from
/// growing the connection buffer without ever sending a newline.
const MAX_CHUNK_SIZE_LINE: usize = 256;

/// The end of the line starting at `i`: `(content_end, next)` where
/// `content` excludes the `\r?\n` terminator and `next` indexes past it.
fn find_line(buf: &[u8], i: usize) -> Option<(usize, usize)> {
    let nl = buf[i..].iter().position(|&b| b == b'\n')? + i;
    let content_end = if nl > i && buf[nl - 1] == b'\r' { nl - 1 } else { nl };
    Some((content_end, nl + 1))
}

/// Decode a `Transfer-Encoding: chunked` body starting at `head_end`.
///
/// Incremental like the head parse: `Ok(None)` until the full chunk
/// stream (terminal chunk + trailer section) is buffered, `Err` the
/// moment the framing can never become valid. Returns the decoded body
/// and the total consumed length (head included). Trailer fields are
/// validated with the same syntax rules as headers, counted against
/// `max_headers`, then discarded.
///
/// Two passes so incomplete bodies cost no allocation: a framing *scan*
/// runs on every call (and is what returns `Ok(None)`/`Err`), and only
/// once the stream is complete does a second walk copy the chunk data
/// into an exactly-sized buffer. A trickled upload therefore re-scans
/// bytes but never re-copies them, and the connection's keep-alive hard
/// cap bounds how long an attacker can drag the re-scans out.
fn parse_chunked_body(
    buf: &[u8],
    head_end: usize,
    limits: &Limits,
) -> Result<Option<(Vec<u8>, usize)>, ParseError> {
    let (total, decoded_len) = match walk_chunks(buf, head_end, limits, None)? {
        Some(v) => v,
        None => return Ok(None),
    };
    let mut body = Vec::with_capacity(decoded_len);
    let done = walk_chunks(buf, head_end, limits, Some(&mut body))?;
    debug_assert_eq!(done, Some((total, decoded_len)));
    Ok(Some((body, total)))
}

/// One walk over a chunked stream: validates framing and, when `body` is
/// given, copies the chunk data into it. Returns `Ok(None)` while the
/// stream is incomplete, else `(consumed_total, decoded_len)`.
fn walk_chunks(
    buf: &[u8],
    head_end: usize,
    limits: &Limits,
    mut body: Option<&mut Vec<u8>>,
) -> Result<Option<(usize, usize)>, ParseError> {
    // Raw-stream budget: the decoded cap alone would let an attacker
    // buffer ~256x max_body of pure framing (1-byte chunks, each padded
    // with a fat extension) without ever finishing the request. 8x
    // decoded leaves room for the worst *legitimate* framing (1-byte
    // chunks cost 6x) while bounding the connection buffer.
    let raw_budget = limits.max_body.saturating_mul(8).max(1024);
    let mut i = head_end;
    let mut decoded = 0usize;
    loop {
        if i - head_end > raw_budget {
            return Err(ParseError::TooLarge("chunked framing exceeds limit"));
        }
        // ---- chunk-size line: HEX[;extension] ----------------------------
        let (line_end, next) = match find_line(buf, i) {
            Some(p) => p,
            None => {
                if buf.len() - i > MAX_CHUNK_SIZE_LINE {
                    return Err(ParseError::Bad("chunk size line too long"));
                }
                return Ok(None);
            }
        };
        if line_end - i > MAX_CHUNK_SIZE_LINE {
            return Err(ParseError::Bad("chunk size line too long"));
        }
        let line = &buf[i..line_end];
        let (size_hex, ext) = match line.iter().position(|&b| b == b';') {
            Some(p) => (&line[..p], &line[p + 1..]),
            None => (line, &line[..0]),
        };
        if size_hex.is_empty()
            || size_hex.len() > 16
            || !size_hex.iter().all(u8::is_ascii_hexdigit)
        {
            return Err(ParseError::Bad("malformed chunk size"));
        }
        if ext.iter().any(|&b| (b < 0x20 && b != b'\t') || b == 0x7f) {
            return Err(ParseError::Bad("malformed chunk extension"));
        }
        // 16 hex digits always fit u64; the size itself is still checked
        // against max_body before any data is accepted
        let size = u64::from_str_radix(std::str::from_utf8(size_hex).unwrap(), 16).unwrap();
        if size as u128 + decoded as u128 > limits.max_body as u128 {
            return Err(ParseError::TooLarge("decoded chunked body exceeds limit"));
        }
        i = next;
        if size == 0 {
            break;
        }
        // ---- chunk data + its CRLF terminator ----------------------------
        let size = size as usize;
        if buf.len() < i + size + 1 {
            return Ok(None); // data (or its terminator) not buffered yet
        }
        if let Some(out) = body.as_mut() {
            out.extend_from_slice(&buf[i..i + size]);
        }
        decoded += size;
        i += size;
        match buf[i] {
            b'\n' => i += 1,
            b'\r' => match buf.get(i + 1) {
                Some(&b'\n') => i += 2,
                Some(_) => return Err(ParseError::Bad("malformed chunk framing")),
                None => return Ok(None),
            },
            _ => return Err(ParseError::Bad("malformed chunk framing")),
        }
    }
    // ---- trailer section: header-syntax lines up to a blank line ---------
    let trailer_start = i;
    let mut fields = 0usize;
    loop {
        let (line_end, next) = match find_line(buf, i) {
            Some(p) => p,
            None => {
                if buf.len() - trailer_start > limits.max_head {
                    return Err(ParseError::TooLarge("trailer section exceeds limit"));
                }
                return Ok(None);
            }
        };
        if next - trailer_start > limits.max_head {
            return Err(ParseError::TooLarge("trailer section exceeds limit"));
        }
        let line = &buf[i..line_end];
        i = next;
        if line.is_empty() {
            return Ok(Some((i, decoded)));
        }
        fields += 1;
        if fields > limits.max_headers {
            return Err(ParseError::TooLarge("too many header fields"));
        }
        let line = std::str::from_utf8(line)
            .map_err(|_| ParseError::Bad("trailer is not valid utf-8"))?;
        if line.starts_with(' ') || line.starts_with('\t') {
            return Err(ParseError::Bad("obsolete header line folding"));
        }
        let (name, value) =
            line.split_once(':').ok_or(ParseError::Bad("trailer line without ':'"))?;
        if name.is_empty() || !name.bytes().all(is_tchar) {
            return Err(ParseError::Bad("invalid trailer name"));
        }
        if value.bytes().any(|b| (b < 0x20 && b != b'\t') || b == 0x7f) {
            return Err(ParseError::Bad("invalid trailer value"));
        }
    }
}

/// Encode `body` as `Transfer-Encoding: chunked` framing onto `out`:
/// hex-size line + data + CRLF per chunk of at most `chunk_size` bytes,
/// then the terminal `0\r\n\r\n` (no trailers). The inverse of
/// [`parse_chunked_body`]'s decoding — round-trips byte-identically —
/// used by the server to stream large response bodies.
pub fn encode_chunked(body: &[u8], chunk_size: usize, out: &mut Vec<u8>) {
    let chunk_size = chunk_size.max(1);
    for chunk in body.chunks(chunk_size) {
        out.extend_from_slice(format!("{:x}\r\n", chunk.len()).as_bytes());
        out.extend_from_slice(chunk);
        out.extend_from_slice(b"\r\n");
    }
    out.extend_from_slice(b"0\r\n\r\n");
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(buf: &[u8]) -> Result<Option<(Request<'_>, usize)>, ParseError> {
        parse_request(buf, &Limits::default())
    }

    fn full(buf: &[u8]) -> (Request<'_>, usize) {
        parse(buf).expect("valid").expect("complete")
    }

    #[test]
    fn parses_get_with_headers() {
        let raw = b"GET /v1/metrics?pretty=1 HTTP/1.1\r\nHost: localhost\r\nX-Trace: abc\r\n\r\n";
        let (req, consumed) = full(raw);
        assert_eq!(consumed, raw.len());
        assert_eq!(req.method, "GET");
        assert_eq!(req.target, "/v1/metrics?pretty=1");
        assert_eq!(req.path(), "/v1/metrics");
        assert_eq!(req.version, Version::Http11);
        assert_eq!(req.header("host"), Some("localhost"));
        assert_eq!(req.header("X-TRACE"), Some("abc"));
        assert_eq!(req.header("missing"), None);
        assert!(req.body.is_empty());
        assert!(req.keep_alive());
    }

    #[test]
    fn parses_post_body_and_preserves_pipelined_bytes() {
        let raw = b"POST /v1/classify HTTP/1.1\r\nContent-Length: 5\r\n\r\nhelloGET / HTTP/1.1\r\n\r\n";
        let (req, consumed) = full(raw);
        assert_eq!(req.method, "POST");
        assert_eq!(&req.body[..], b"hello");
        // the pipelined second request is untouched past `consumed`
        assert!(raw[consumed..].starts_with(b"GET / "));
        let (req2, consumed2) = full(&raw[consumed..]);
        assert_eq!(req2.method, "GET");
        assert_eq!(consumed + consumed2, raw.len());
    }

    #[test]
    fn bare_lf_line_endings_accepted() {
        let raw = b"POST /x HTTP/1.1\nContent-Length: 2\n\nok";
        let (req, consumed) = full(raw);
        assert_eq!(&req.body[..], b"ok");
        assert_eq!(consumed, raw.len());
    }

    #[test]
    fn every_prefix_of_a_valid_request_is_incomplete_not_an_error() {
        // the incremental contract: for EVERY split point, the prefix
        // parses to Ok(None) and the full buffer parses identically —
        // so the server's read-loop behaves the same no matter how the
        // bytes are chunked across read() boundaries
        let raw: &[u8] =
            b"POST /v1/classify HTTP/1.1\r\nHost: t\r\nContent-Length: 11\r\n\r\n{\"image\":1}";
        for cut in 0..raw.len() {
            match parse(&raw[..cut]) {
                Ok(None) => {}
                other => panic!("prefix {cut} must be incomplete, got {other:?}"),
            }
        }
        let (req, consumed) = full(raw);
        assert_eq!(consumed, raw.len());
        assert_eq!(&req.body[..], b"{\"image\":1}");
    }

    #[test]
    fn keep_alive_matrix() {
        let ka = |raw: &[u8]| full(raw).0.keep_alive();
        assert!(ka(b"GET / HTTP/1.1\r\n\r\n"));
        assert!(!ka(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n"));
        assert!(!ka(b"GET / HTTP/1.1\r\nConnection: Close\r\n\r\n"));
        assert!(!ka(b"GET / HTTP/1.0\r\n\r\n"));
        assert!(ka(b"GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n"));
        assert!(ka(b"GET / HTTP/1.1\r\nConnection: foo, keep-alive\r\n\r\n"));
    }

    #[test]
    fn malformed_requests_rejected() {
        let bad = |raw: &[u8]| match parse(raw) {
            Err(ParseError::Bad(m)) => m,
            other => panic!("expected Bad, got {other:?}"),
        };
        bad(b"GET / FTP/1.1\r\n\r\n");
        bad(b"GET / HTTP/2.0\r\n\r\n");
        bad(b"GET  / HTTP/1.1\r\n\r\n"); // double space -> empty target
        bad(b"G<T / HTTP/1.1\r\n\r\n"); // invalid method token
        bad(b"GET /a b HTTP/1.1\r\n\r\n"); // four request-line parts
        bad(b"GET / HTTP/1.1\r\nNoColonHere\r\n\r\n");
        bad(b"GET / HTTP/1.1\r\nHost : x\r\n\r\n"); // space before colon
        bad(b"GET / HTTP/1.1\r\nA: b\r\n\tfolded\r\n\r\n"); // obs-fold
        bad(b"POST / HTTP/1.1\r\nContent-Length: abc\r\n\r\n");
        bad(b"POST / HTTP/1.1\r\nContent-Length: -1\r\n\r\n");
        bad(b"POST / HTTP/1.1\r\nContent-Length: 1\r\nContent-Length: 2\r\n\r\n");
        // only *exactly* `chunked` is an implemented transfer coding
        bad(b"POST / HTTP/1.1\r\nTransfer-Encoding: gzip\r\n\r\n");
        bad(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked, gzip\r\n\r\n");
        // chunked alongside content-length is the classic smuggling vector
        bad(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\nContent-Length: 3\r\n\r\n");
        // duplicate TE headers are rejected even when both say chunked
        bad(b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\nTransfer-Encoding: chunked\r\n\r\n");
        bad(b"\r\nGET / HTTP/1.1\r\n\r\n"); // leading blank line
    }

    #[test]
    fn duplicate_equal_content_lengths_are_tolerated() {
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nhi";
        let (req, _) = full(raw);
        assert_eq!(&req.body[..], b"hi");
    }

    #[test]
    fn size_limits_map_to_too_large() {
        let limits = Limits { max_head: 64, max_headers: 2, max_body: 16 };
        // oversized head, even before the terminator arrives
        let long = vec![b'A'; 100];
        assert!(matches!(
            parse_request(&long, &limits),
            Err(ParseError::TooLarge("request head exceeds limit"))
        ));
        // too many header fields
        let raw = b"GET / HTTP/1.1\nA: 1\nB: 2\nC: 3\n\n";
        assert!(matches!(parse_request(raw, &limits), Err(ParseError::TooLarge(_))));
        // declared body over the limit: rejected from the head alone
        let raw = b"POST / HTTP/1.1\nContent-Length: 17\n\n";
        assert!(matches!(parse_request(raw, &limits), Err(ParseError::TooLarge(_))));
        // absurd content-length that overflows usize parsing
        let raw = b"POST / HTTP/1.1\nContent-Length: 99999999999999999999999999\n\n";
        assert!(matches!(parse_request(raw, &limits), Err(ParseError::TooLarge(_))));
    }

    #[test]
    fn empty_buffer_is_incomplete() {
        assert!(matches!(parse(b""), Ok(None)));
        assert!(matches!(parse(b"GET"), Ok(None)));
    }

    // ---- chunked bodies ---------------------------------------------------

    #[test]
    fn chunked_body_decodes_and_preserves_pipelined_bytes() {
        let raw =
            b"POST /v1/classify HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
              5\r\nhello\r\n6\r\n world\r\n0\r\n\r\nGET / HTTP/1.1\r\n\r\n";
        let (req, consumed) = full(raw);
        assert_eq!(req.method, "POST");
        assert_eq!(&req.body[..], b"hello world");
        assert!(matches!(req.body, std::borrow::Cow::Owned(_)));
        // the pipelined second request is untouched past `consumed`
        assert!(raw[consumed..].starts_with(b"GET / "));
        let (req2, consumed2) = full(&raw[consumed..]);
        assert_eq!(req2.method, "GET");
        assert_eq!(consumed + consumed2, raw.len());
    }

    #[test]
    fn chunked_empty_body_and_bare_lf_framing() {
        let raw = b"POST /x HTTP/1.1\nTransfer-Encoding: chunked\n\n0\n\n";
        let (req, consumed) = full(raw);
        assert!(req.body.is_empty());
        assert_eq!(consumed, raw.len());
    }

    #[test]
    fn chunked_extensions_ignored_and_trailers_validated_then_discarded() {
        let raw = b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
                    4;name=value\r\nabcd\r\n0\r\nX-Sum: 7\r\nX-Trace: t\r\n\r\n";
        let (req, consumed) = full(raw);
        assert_eq!(&req.body[..], b"abcd");
        assert_eq!(consumed, raw.len());
        // trailers are framing, not headers: they never join the header map
        assert_eq!(req.header("x-sum"), None);
    }

    #[test]
    fn chunked_hex_sizes_parse_as_hex() {
        // 0x10 = 16 data bytes
        let raw = b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
                    10\r\nABCDEFGHIJKLMNOP\r\n0\r\n\r\n";
        let (req, _) = full(raw);
        assert_eq!(&req.body[..], b"ABCDEFGHIJKLMNOP");
    }

    #[test]
    fn every_prefix_of_a_valid_chunked_request_is_incomplete_not_an_error() {
        let raw: &[u8] = b"POST /c HTTP/1.1\r\nHost: t\r\nTransfer-Encoding: chunked\r\n\r\n\
                           3\r\nabc\r\n2;x=y\r\nde\r\n0\r\nX-T: v\r\n\r\n";
        for cut in 0..raw.len() {
            match parse(&raw[..cut]) {
                Ok(None) => {}
                other => panic!("prefix {cut} must be incomplete, got {other:?}"),
            }
        }
        let (req, consumed) = full(raw);
        assert_eq!(consumed, raw.len());
        assert_eq!(&req.body[..], b"abcde");
    }

    #[test]
    fn malformed_chunked_framing_rejected() {
        let bad = |raw: &[u8]| match parse(raw) {
            Err(ParseError::Bad(m)) => m,
            other => panic!("expected Bad, got {other:?}"),
        };
        let req = |tail: &str| {
            let mut v = b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec();
            v.extend_from_slice(tail.as_bytes());
            v
        };
        bad(&req("zz\r\nab\r\n0\r\n\r\n")); // non-hex size
        bad(&req("\r\nab\r\n0\r\n\r\n")); // empty size line
        bad(&req("2\r\nabXX")); // data not followed by CRLF
        bad(&req("2\r\nab\rX")); // CR followed by non-LF
        bad(&req("0\r\n folded\r\n\r\n")); // trailer obs-fold
        bad(&req("0\r\nNoColon\r\n\r\n")); // trailer without ':'
        bad(&req("0\r\nBad Name: v\r\n\r\n")); // trailer name with space
        // size line that can never terminate
        let mut long = req("");
        long.extend_from_slice(&vec![b'1'; 300]);
        bad(&long);
    }

    #[test]
    fn chunked_framing_amplification_is_bounded() {
        // an attacker drip-feeding 1-byte chunks padded with fat
        // extensions must hit the raw-framing budget (8x max_body) long
        // before the connection buffer grows without bound — even though
        // the *decoded* size stays tiny and the stream never completes
        let limits = Limits { max_head: 1024, max_headers: 16, max_body: 16 };
        let mut raw = b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec();
        let padded_chunk = format!("1;{}\r\nX\r\n", "a".repeat(200));
        for _ in 0..8 {
            raw.extend_from_slice(padded_chunk.as_bytes());
        }
        // 8 chunks x ~208 raw bytes for 8 decoded bytes: over the budget
        assert!(matches!(
            parse_request(&raw, &limits),
            Err(ParseError::TooLarge("chunked framing exceeds limit"))
        ));
        // minimal framing for a full-size body stays comfortably legal
        let mut ok = b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec();
        for _ in 0..16 {
            ok.extend_from_slice(b"1\r\nX\r\n");
        }
        ok.extend_from_slice(b"0\r\n\r\n");
        let (req, _) = parse_request(&ok, &limits).unwrap().unwrap();
        assert_eq!(&req.body[..], b"XXXXXXXXXXXXXXXX");
    }

    #[test]
    fn chunked_body_over_limit_is_too_large() {
        let limits = Limits { max_head: 1024, max_headers: 16, max_body: 8 };
        // declared chunk alone exceeds the cap: rejected before any data
        let raw = b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n9\r\n";
        assert!(matches!(parse_request(raw, &limits), Err(ParseError::TooLarge(_))));
        // cumulative decoded size crosses the cap on a later chunk
        let raw = b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
                    6\r\nabcdef\r\n6\r\nghijkl\r\n0\r\n\r\n";
        assert!(matches!(parse_request(raw, &limits), Err(ParseError::TooLarge(_))));
        // within the cap parses fine
        let raw = b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
                    4\r\nabcd\r\n4\r\nefgh\r\n0\r\n\r\n";
        let (req, _) = parse_request(raw, &limits).unwrap().unwrap();
        assert_eq!(&req.body[..], b"abcdefgh");
    }

    #[test]
    fn encode_chunked_roundtrips_through_the_parser() {
        // every (body length, chunk size) combination must decode back
        // byte-identically — including empty bodies (bare terminator) and
        // chunk sizes larger than the body (single chunk)
        for len in [0usize, 1, 5, 16, 17, 100] {
            let body: Vec<u8> = (0..len).map(|i| (i % 251) as u8).collect();
            for chunk in [1usize, 4, 16, 64] {
                let mut framed =
                    b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec();
                encode_chunked(&body, chunk, &mut framed);
                let (req, consumed) = parse(&framed).expect("valid").expect("complete");
                assert_eq!(consumed, framed.len(), "len={len} chunk={chunk}");
                assert_eq!(&req.body[..], &body[..], "len={len} chunk={chunk}");
            }
        }
    }

    #[test]
    fn encode_chunked_emits_hex_sizes() {
        let mut out = Vec::new();
        encode_chunked(&[b'a'; 26], 26, &mut out);
        assert_eq!(&out[..], b"1a\r\naaaaaaaaaaaaaaaaaaaaaaaaaa\r\n0\r\n\r\n");
        // a zero chunk size is clamped rather than looping forever
        let mut out = Vec::new();
        encode_chunked(b"xy", 0, &mut out);
        assert_eq!(&out[..], b"1\r\nx\r\n1\r\ny\r\n0\r\n\r\n");
    }
}
