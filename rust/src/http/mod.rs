//! Hand-rolled HTTP/1.1 serving front-end (no hyper/tonic/tokio offline).
//!
//! This is the network boundary in front of the persistent serving runtime
//! [`crate::coordinator::Server`]: a [`server::HttpServer`] accepts loopback
//! or LAN TCP connections, parses requests incrementally and zero-copy
//! ([`parser`]), decodes classification payloads into `Server::submit`
//! calls with per-request deadlines, and streams back JSON built with
//! [`crate::util::json`]. Connection handling rides the bounded
//! [`crate::util::pool::WorkerPool`]; saturated pools shed with `503`
//! instead of queueing without bound.
//!
//! # Wire protocol
//!
//! Only HTTP/1.1 and HTTP/1.0 are spoken. Persistent connections follow
//! the usual defaults (1.1 keep-alive unless `Connection: close`; 1.0
//! close unless `Connection: keep-alive`) and pipelined requests on one
//! connection are answered in order. Request bodies require
//! `Content-Length`; `Transfer-Encoding` (chunked) is rejected with `400`
//! rather than ignored, closing a request-smuggling vector.
//!
//! ## `POST /v1/classify`
//!
//! Request body (`Content-Type: application/json`):
//!
//! ```json
//! {"image": [0.1, 0.5, ...], "id": 7, "deadline_ms": 50.0}
//! ```
//!
//! * `image` — required; flat row-major pixel array matching the model's
//!   input dimension.
//! * `id` — optional client request id, echoed back verbatim;
//!   auto-assigned when absent. A present but non-integer or negative
//!   `id` is rejected with `400` (never silently replaced).
//! * `deadline_ms` — optional per-request deadline. If the request is
//!   still queued when it expires, workers skip it *before* it touches an
//!   engine and the response is `504` with an `"error"` body. Without it
//!   the coordinator's `ServerConfig::default_deadline` applies.
//!
//! `200` response body:
//!
//! ```json
//! {"id": 7, "class": 3, "queue_us": 120.0, "compute_us": 850.0,
//!  "latency_us": 990.0, "batch_size": 8}
//! ```
//!
//! ## `GET /v1/metrics`
//!
//! `200` with the live [`crate::coordinator::ServeMetrics`] snapshot:
//! request/error/expired counters, batch stats, and
//! mean/p50/p95/p99/max summaries for the end-to-end latency, queue-wait
//! and compute recorders.
//!
//! ## `GET /healthz`
//!
//! `200` with `{"status":"ok"}` — liveness only.
//!
//! ## Status codes
//!
//! | code | meaning |
//! |------|---------|
//! | 200  | classified / snapshot served |
//! | 400  | malformed HTTP (bad request line, header, `Content-Length`, chunked), invalid JSON, missing/wrong-size `image` |
//! | 404  | unknown path |
//! | 405  | wrong method on a known path (`Allow` header lists the right one) |
//! | 408  | a partial request stalled past the keep-alive timeout |
//! | 413  | head or declared body over the configured limits |
//! | 500  | engine failure on the batch the request rode in |
//! | 503  | request queue full, connection backlog full, or shutting down |
//! | 504  | per-request deadline expired in queue, or the response-wait backstop fired |
//!
//! All error bodies are `{"error": "<message>"}`. Protocol-level errors
//! (400/413/408) close the connection; semantic errors (404/405 and the
//! JSON-level 400s) keep it open per the usual keep-alive rules.

pub mod parser;
pub mod server;

pub use parser::{parse_request, Limits, ParseError, Request, Version};
pub use server::{HttpConfig, HttpServer};
