//! Hand-rolled HTTP/1.1 serving front-end (no hyper/tonic/tokio offline).
//!
//! This is the network boundary in front of the multi-model serving
//! [`crate::coordinator::Router`]: a [`server::HttpServer`] accepts
//! loopback or LAN TCP connections, parses requests incrementally and
//! zero-copy ([`parser`]), decodes classification payloads into routed
//! `Router::try_submit` calls with per-request deadlines, and streams back
//! JSON built with [`crate::util::json`].
//!
//! # Connection backends
//!
//! On Linux (default; [`HttpConfig::event_loop`]) connections are served
//! by a **readiness-driven event loop**: one thread multiplexes every
//! socket over a vendored `epoll` shim (raw syscalls, no tokio) with
//! per-connection state machines, write-interest registration for
//! partially flushed responses, and a timer wheel for keep-alive /
//! slow-drip deadlines. Tens of thousands of mostly idle keep-alive
//! connections cost one loop thread plus `conn_threads` classify
//! workers; [`HttpConfig::max_connections`] caps the open-socket count
//! (accepts past it shed with `503`). Everywhere else — or with
//! `event_loop` off — the **blocking fallback** runs: a bounded
//! [`crate::util::pool::WorkerPool`] of connection-handler threads fed
//! by an accept loop, shedding with `503` when pool + backlog saturate.
//! Both backends share the parser, the routing layer, and the response
//! encoder, so observable behaviour is identical below the
//! concurrency-scale difference.
//!
//! # Wire protocol
//!
//! Only HTTP/1.1 and HTTP/1.0 are spoken. Persistent connections follow
//! the usual defaults (1.1 keep-alive unless `Connection: close`; 1.0
//! close unless `Connection: keep-alive`) and pipelined requests on one
//! connection are answered in order. Request bodies are framed by
//! `Content-Length` or `Transfer-Encoding: chunked` (sizes in hex,
//! extensions ignored, trailers validated then discarded; the *decoded*
//! body honours the body limit). Any other transfer coding — or chunked
//! combined with `Content-Length` — is rejected with `400`, closing the
//! request-smuggling vectors.
//!
//! **Response framing**: bodies at or under
//! [`HttpConfig::stream_threshold`] (default 64 KiB) are sent with
//! `Content-Length`; larger bodies — `/v1/models` and `/v1/metrics` over
//! a big fleet, batch classify results — stream to HTTP/1.1 clients as
//! `Transfer-Encoding: chunked` (16 KiB chunks, no trailers), with a
//! **byte-identical decoded payload** to the buffered path. HTTP/1.0
//! clients and `HEAD` responses always get `Content-Length`.
//!
//! **`HEAD` semantics** (RFC 9110 §9.3.2): every `GET` endpoint answers
//! `HEAD` with the same status and headers — including the
//! `Content-Length` the `GET` body would have — and no body, so
//! load-balancer health probes on `/healthz` see `200`. Wrong-method
//! `405`s carry `Allow: GET, HEAD` (or `Allow: POST` on `/v1/classify`).
//!
//! ## `POST /v1/classify`
//!
//! Request body (`Content-Type: application/json`):
//!
//! ```json
//! {"image": [0.1, 0.5, ...], "model": "mlp1_w8a8", "id": 7,
//!  "deadline_ms": 50.0, "acc_bits": 24}
//! ```
//!
//! * `image` — required; flat row-major pixel array matching the target
//!   model's input dimension.
//! * `model` — optional model name to route to. Absent = the default
//!   model (so pre-multi-model clients keep working unchanged). An
//!   unregistered name is answered `404` with an `"error"` body naming
//!   the miss and listing the registered fleet; a registered model is
//!   loaded lazily on its first request (and may be LRU-evicted under the
//!   router's `max_loaded` cap — the next request reloads it). A
//!   present-but-non-string `model` is `400`.
//! * `id` — optional client request id, echoed back verbatim;
//!   auto-assigned when absent. A present but non-integer or negative
//!   `id` is rejected with `400` (never silently replaced).
//! * `deadline_ms` — optional per-request deadline. If the request is
//!   still queued when it expires, workers skip it *before* it touches an
//!   engine and the response is `504` with an `"error"` body. Without it
//!   the router's `ServerConfig::default_deadline` applies.
//! * `acc_bits` (alias `operating_point`; giving both is `400`) —
//!   optional accumulator operating point: a positive integer width the
//!   routed model should run THIS request at, against the same resident
//!   weights. Each layer runs at `min(acc_bits, analytic_bits)` — at
//!   least its planned width, never past its analytic guarantee — so a
//!   wide request (e.g. `32`) buys overflow headroom without loading a
//!   second model. Requires a model with an embedded accumulator plan;
//!   a plan-free model, or a width below the plan's safe minimum (its
//!   widest planned layer), is answered `400` per-request without
//!   disturbing batch-mates. Absent = the embedded plan's own widths.
//!
//! `200` response body:
//!
//! ```json
//! {"id": 7, "class": 3, "queue_us": 120.0, "compute_us": 850.0,
//!  "latency_us": 990.0, "batch_size": 8}
//! ```
//!
//! ## `GET /v1/models`
//!
//! `200` with the registered fleet: the default route plus one row per
//! model — `name`, `default`, `loaded` (is a live server holding it right
//! now), `input_shape` (`null` until knowable), the model's embedded
//! accumulator-bitwidth `plan` summary (`null` for plan-free models;
//! populated once loaded, and pre-load for in-memory sources),
//! `resident_bytes` (the live incarnation's measured weight bytes —
//! owned weights plus its shared file blob; `null` while unloaded), and
//! the model's lifetime `metrics` (which survive LRU eviction):
//!
//! ```json
//! {"default": "a",
//!  "models": [{"name": "a", "default": true, "loaded": true,
//!              "input_shape": [1, 64, 1],
//!              "plan": {"planner": "calibrated", "layers": 3,
//!                       "min_bits": 11, "max_bits": 14,
//!                       "mean_bits": 12.3},
//!              "resident_bytes": 51240,
//!              "metrics": {"requests": 12, "...": "..."}}]}
//! ```
//!
//! The `plan` fields mirror [`crate::plan::PlanSummary`]: `planner` is
//! `"analytic"` (worst-case guaranteed widths) or `"calibrated"`
//! (empirically tightened, capped at the analytic bound), and
//! `min`/`max`/`mean_bits` summarize the enforced per-layer accumulator
//! widths the engine runs this model at.
//!
//! ## `GET /v1/metrics`
//!
//! `200` with the full metrics tree: fleet-wide aggregate counters and
//! latency/queue/compute summaries at the top level (single-model clients
//! keep working), a `router` section (`routed`, `unknown_model`, `loads`,
//! `evictions`, `resident_bytes` — deduped fleet-wide weight bytes, each
//! shared blob counted once — the configured byte `budget` (`0` =
//! unlimited), `dedup_hits`, `load_latency`), per-model
//! [`crate::coordinator::ServeSummary`]
//! sections under `models` keyed by name, the front-end's own `http`
//! counters (`accepted`/`shed`/`read_timeouts` connections), and the
//! shared compute `pool` utilization (`null` when engines run
//! single-threaded). Latency objects carry quantile *summaries*
//! (`count`/`mean_us`/`p50_us`/`p95_us`/`p99_us`/`p999_us`/`max_us`);
//! scrapes are
//! cheap by construction — assembling one never copies a latency
//! reservoir or blocks request routing behind the router lock. (`p999_us`
//! reads from the same uniform reservoir as the other quantiles; it needs
//! roughly a thousand samples before it separates from `max_us`.) The
//! top-level (fleet-aggregate) p50/p95/p99 are count-weighted averages
//! of the per-model quantiles, not pooled quantiles: on a fleet of
//! models with very different latency profiles, read the per-model
//! `models.*` sections for real tails (`count`/`mean_us`/`max_us` are
//! exact at every level).
//!
//! Each per-model section (and each `/v1/models` row) also carries a
//! `health` object — circuit-breaker position and self-healing counters
//! (see below) — and the `router` section totals them as
//! `load_retries` / `breaker_opens` / `breaker_fast_fails` /
//! `quarantined`.
//!
//! ## `GET /healthz` vs `GET /readyz`
//!
//! Two probes with different questions:
//!
//! * **`/healthz` — liveness.** "Is the process alive?" Always `200`
//!   `{"status":"ok"}` while the front-end runs — even mid-drain, even
//!   with every model broken. Restart-deciders point here: flapping it
//!   on transient trouble turns a degraded fleet into a crash loop.
//! * **`/readyz` — readiness.** "Should NEW traffic come here?" `200`
//!   only when every gate holds, else `503` + `Retry-After: 1`; the
//!   JSON body always reports the individual gates
//!   (`ready`/`draining`/`default_model_ok`/`queue_len`/`queue_cap`):
//!   1. not draining — [`HttpServer::set_draining`] (and shutdown,
//!      which calls it first) flips this *before* any connection
//!      closes, so a load balancer stops routing while in-flight
//!      requests still finish;
//!   2. the default model is serviceable — neither quarantined nor
//!      behind an Open load circuit breaker (unloaded-but-loadable
//!      counts as ready: the first request pays the load);
//!   3. the default model's queue sits below a 90% high-watermark —
//!      readiness sheds load *before* submissions start bouncing 503.
//!
//! ## Failure modes
//!
//! Every failure an operator can see on the wire, with its cause, extra
//! headers, and the counter that records it:
//!
//! | code | cause | headers | counted in |
//! |------|-------|---------|------------|
//! | 400  | malformed HTTP (bad request line, header, `Content-Length`, chunk framing, unsupported transfer coding), invalid JSON, missing/wrong-size `image`, non-string `model`, malformed `acc_bits` (non-positive, non-integer, or given together with `operating_point`), an `acc_bits` below the plan's safe minimum, or an `acc_bits` override on a plan-free model | — | per-model `errors` (JSON-level only; protocol 400s never reach a queue) |
//! | 404  | unknown path, or `model` names an unregistered model (body lists the registered fleet) | — | `router.unknown_model` |
//! | 405  | wrong method on a known path | `Allow: GET, HEAD` or `Allow: POST` | — |
//! | 408  | a partial request stalled past the keep-alive timeout, or a whole request failed to arrive within it | — | `http.read_timeouts` |
//! | 413  | head, declared body, or decoded chunked body over the configured limits | — | — |
//! | 500  | engine failure on the batch the request rode in — including a **worker panic**, which is caught per batch (`catch_unwind`): every rider is answered, the engine is rebuilt, the worker survives — or a registered model's load failed (missing file, injected fault, over the `--max-bytes` budget) | — | per-model `errors`; panics also in per-model `panics` |
//! | 503  | **queue full** (target model's queue, classify worker backlog, connection backlog / `max_connections` cap) — transient, retry | `Retry-After: 1` | `http.shed` (connection-level) |
//! | 503  | **breaker open**: the model's recent loads kept failing; requests fast-fail without touching the source until the backoff elapses | `Retry-After:` ceil of the remaining backoff | `router.breaker_fast_fails`, per-model `health.fast_fails` |
//! | 503  | **quarantined**: the model failed an integrity check (checksum mismatch, plan/graph inconsistency); only an explicit reload ends it | — (no `Retry-After`: waiting cannot fix corrupt bytes) | `router.quarantined`, per-model `health` |
//! | 503  | shutting down / draining | — | — |
//! | 504  | per-request deadline expired in queue, or the response-wait backstop fired | `Retry-After: 1` | per-model `expired` |
//!
//! All error bodies are `{"error": "<message>"}`. Protocol-level errors
//! (400/413/408) close the connection; semantic errors (404/405 and the
//! JSON-level 400s) keep it open per the usual keep-alive rules.

#[cfg(target_os = "linux")]
mod event_loop;
pub mod parser;
pub mod server;

pub use parser::{parse_request, Limits, ParseError, Request, Version};
pub use server::{FrontendReport, HttpConfig, HttpMetrics, HttpServer};
