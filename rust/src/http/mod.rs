//! Hand-rolled HTTP/1.1 serving front-end (no hyper/tonic/tokio offline).
//!
//! This is the network boundary in front of the multi-model serving
//! [`crate::coordinator::Router`]: a [`server::HttpServer`] accepts
//! loopback or LAN TCP connections, parses requests incrementally and
//! zero-copy ([`parser`]), decodes classification payloads into routed
//! `Router::try_submit` calls with per-request deadlines, and streams back
//! JSON built with [`crate::util::json`].
//!
//! # Connection backends
//!
//! On Linux (default; [`HttpConfig::event_loop`]) connections are served
//! by a **readiness-driven event loop**: one thread multiplexes every
//! socket over a vendored `epoll` shim (raw syscalls, no tokio) with
//! per-connection state machines, write-interest registration for
//! partially flushed responses, and a timer wheel for keep-alive /
//! slow-drip deadlines. Tens of thousands of mostly idle keep-alive
//! connections cost one loop thread plus `conn_threads` classify
//! workers; [`HttpConfig::max_connections`] caps the open-socket count
//! (accepts past it shed with `503`). Everywhere else — or with
//! `event_loop` off — the **blocking fallback** runs: a bounded
//! [`crate::util::pool::WorkerPool`] of connection-handler threads fed
//! by an accept loop, shedding with `503` when pool + backlog saturate.
//! Both backends share the parser, the routing layer, and the response
//! encoder, so observable behaviour is identical below the
//! concurrency-scale difference.
//!
//! # Wire protocol
//!
//! Only HTTP/1.1 and HTTP/1.0 are spoken. Persistent connections follow
//! the usual defaults (1.1 keep-alive unless `Connection: close`; 1.0
//! close unless `Connection: keep-alive`) and pipelined requests on one
//! connection are answered in order. Request bodies are framed by
//! `Content-Length` or `Transfer-Encoding: chunked` (sizes in hex,
//! extensions ignored, trailers validated then discarded; the *decoded*
//! body honours the body limit). Any other transfer coding — or chunked
//! combined with `Content-Length` — is rejected with `400`, closing the
//! request-smuggling vectors.
//!
//! **Response framing**: bodies at or under
//! [`HttpConfig::stream_threshold`] (default 64 KiB) are sent with
//! `Content-Length`; larger bodies — `/v1/models` and `/v1/metrics` over
//! a big fleet, batch classify results — stream to HTTP/1.1 clients as
//! `Transfer-Encoding: chunked` (16 KiB chunks, no trailers), with a
//! **byte-identical decoded payload** to the buffered path. HTTP/1.0
//! clients and `HEAD` responses always get `Content-Length`.
//!
//! **`HEAD` semantics** (RFC 9110 §9.3.2): every `GET` endpoint answers
//! `HEAD` with the same status and headers — including the
//! `Content-Length` the `GET` body would have — and no body, so
//! load-balancer health probes on `/healthz` see `200`. Wrong-method
//! `405`s carry `Allow: GET, HEAD` (or `Allow: POST` on `/v1/classify`).
//!
//! ## `POST /v1/classify`
//!
//! Request body (`Content-Type: application/json`):
//!
//! ```json
//! {"image": [0.1, 0.5, ...], "model": "mlp1_w8a8", "id": 7,
//!  "deadline_ms": 50.0, "acc_bits": 24}
//! ```
//!
//! * `image` — required; flat row-major pixel array matching the target
//!   model's input dimension.
//! * `model` — optional model name to route to. Absent = the default
//!   model (so pre-multi-model clients keep working unchanged). An
//!   unregistered name is answered `404` with an `"error"` body naming
//!   the miss and listing the registered fleet; a registered model is
//!   loaded lazily on its first request (and may be LRU-evicted under the
//!   router's `max_loaded` cap — the next request reloads it). A
//!   present-but-non-string `model` is `400`.
//! * `id` — optional client request id, echoed back verbatim;
//!   auto-assigned when absent. A present but non-integer or negative
//!   `id` is rejected with `400` (never silently replaced).
//! * `deadline_ms` — optional per-request deadline. If the request is
//!   still queued when it expires, workers skip it *before* it touches an
//!   engine and the response is `504` with an `"error"` body. Without it
//!   the router's `ServerConfig::default_deadline` applies.
//! * `acc_bits` (alias `operating_point`; giving both is `400`) —
//!   optional accumulator operating point: a positive integer width the
//!   routed model should run THIS request at, against the same resident
//!   weights. Each layer runs at `min(acc_bits, analytic_bits)` — at
//!   least its planned width, never past its analytic guarantee — so a
//!   wide request (e.g. `32`) buys overflow headroom without loading a
//!   second model. Requires a model with an embedded accumulator plan;
//!   a plan-free model, or a width below the plan's safe minimum (its
//!   widest planned layer), is answered `400` per-request without
//!   disturbing batch-mates. Absent = the embedded plan's own widths.
//!
//! `200` response body:
//!
//! ```json
//! {"id": 7, "class": 3, "queue_us": 120.0, "compute_us": 850.0,
//!  "latency_us": 990.0, "batch_size": 8}
//! ```
//!
//! ## `GET /v1/models`
//!
//! `200` with the registered fleet: the default route plus one row per
//! model — `name`, `default`, `loaded` (is a live server holding it right
//! now), `input_shape` (`null` until knowable), the model's embedded
//! accumulator-bitwidth `plan` summary (`null` for plan-free models;
//! populated once loaded, and pre-load for in-memory sources),
//! `resident_bytes` (the live incarnation's measured weight bytes —
//! owned weights plus its shared file blob; `null` while unloaded),
//! the model's lifetime `metrics` (which survive LRU eviction), and —
//! while a live engine holds the model — a `headroom` array of live
//! per-layer accumulator telemetry (`null` when unloaded, `[]` until a
//! batch has run; the same rows the Prometheus `pqs_headroom_*` gauges
//! export, see `GET /metrics` below):
//!
//! ```json
//! {"default": "a",
//!  "models": [{"name": "a", "default": true, "loaded": true,
//!              "input_shape": [1, 64, 1],
//!              "plan": {"planner": "calibrated", "layers": 3,
//!                       "min_bits": 11, "max_bits": 14,
//!                       "mean_bits": 12.3},
//!              "resident_bytes": 51240,
//!              "metrics": {"requests": 12, "...": "..."},
//!              "headroom": [{"layer": "fc1", "planned_bits": 12,
//!                            "max_required_bits": 10,
//!                            "min_headroom_bits": 2, "dots": 4096,
//!                            "overflow_dots": 0,
//!                            "near_saturation_dots": 0,
//!                            "batches": 4}]}]}
//! ```
//!
//! The `plan` fields mirror [`crate::plan::PlanSummary`]: `planner` is
//! `"analytic"` (worst-case guaranteed widths) or `"calibrated"`
//! (empirically tightened, capped at the analytic bound), and
//! `min`/`max`/`mean_bits` summarize the enforced per-layer accumulator
//! widths the engine runs this model at.
//!
//! ## `GET /v1/metrics`
//!
//! `200` with the full metrics tree: fleet-wide aggregate counters and
//! latency/queue/compute summaries at the top level (single-model clients
//! keep working), a `router` section (`routed`, `unknown_model`, `loads`,
//! `evictions`, `resident_bytes` — deduped fleet-wide weight bytes, each
//! shared blob counted once — the configured byte `budget` (`0` =
//! unlimited), `dedup_hits`, `load_latency`), per-model
//! [`crate::coordinator::ServeSummary`]
//! sections under `models` keyed by name, the front-end's own `http`
//! counters (`accepted`, `read_timeouts`, and `shed` broken out per
//! reason: `shed_queue_full` / `shed_max_connections` / `shed_draining`),
//! a `trace` section (sampling state plus per-stage span-duration
//! quantiles — see the span-stage glossary below), and the shared
//! compute `pool` utilization (`null` when engines run single-threaded).
//! Latency objects carry quantile *summaries*
//! (`count`/`mean_us`/`p50_us`/`p95_us`/`p99_us`/`p999_us`/`max_us`);
//! scrapes are cheap by construction — assembling one never copies a
//! latency reservoir or blocks request routing behind the router lock.
//! (`p999_us` reads from the same uniform reservoir as the other
//! quantiles; it needs roughly a thousand samples before it separates
//! from `max_us`.) Fleet-aggregate and lifetime (eviction-surviving)
//! quantiles are *pooled* through merged HDR histograms — within the
//! histogram's ~3% bucket resolution of the true pooled quantile, never
//! a count-weighted average of per-model quantiles.
//!
//! Each per-model section (and each `/v1/models` row) also carries a
//! `health` object — circuit-breaker position and self-healing counters
//! (see below) — and the `router` section totals them as
//! `load_retries` / `breaker_opens` / `breaker_fast_fails` /
//! `quarantined`.
//!
//! ## Request tracing: `X-Request-Id` and `GET /v1/trace`
//!
//! Every `/v1/classify` response carries an `X-Request-Id` header while
//! tracing is enabled (the default — `--trace-sample-rate` controls
//! ring sampling, not the id echo): the id is taken verbatim from the
//! request's own `X-Request-Id` header when present — 1..=128
//! characters of `[A-Za-z0-9._-]`, anything else is rejected `400` —
//! and generated (`pqs-` + 16 hex digits) otherwise, so a client can
//! correlate a response, a log line, and a trace span without minting
//! ids itself.
//!
//! Each traced request records a **span**: total wall time plus a
//! six-stage decomposition, clamped so the stages never sum past the
//! honest total. The span-stage glossary:
//!
//! * `parse_us` — HTTP read + JSON decode: arrival to a validated
//!   classify request;
//! * `route_us` — routing: model lookup, breaker gate, lazy-load wait,
//!   queue admission (`try_submit` entry to return);
//! * `queue_us` — waiting in the routed model's queue for a worker;
//! * `batch_us` — batch assembly (the linger window collecting
//!   batch-mates);
//! * `forward_us` — the engine forward pass the request rode in (the
//!   span also carries per-layer timings for its batch);
//! * `respond_us` — response encoding up to the flush handoff.
//!
//! Stage durations feed the `/v1/metrics` `trace` histograms for every
//! request; whole spans land in a bounded in-memory ring when
//! head-sampled, or unconditionally on errors, overflow-flagged
//! forwards, and sheds (a shed records a synthetic 503 span carrying
//! its reason). `GET /v1/trace?n=K` returns the most recent `K` ring
//! spans oldest-first (everything buffered without `n`) plus sampling
//! state and recorded/dropped counters; the ring never blocks the
//! request path — old spans are evicted, not flushed.
//!
//! ## `GET /metrics` — Prometheus text exposition
//!
//! The same counters, gauges and distributions in Prometheus text
//! format 0.0.4 (`Content-Type: text/plain; version=0.0.4`) for scrape
//! pipelines: `pqs_*_total` counters (requests, errors, sheds by
//! `reason`, router loads/evictions, trace spans), byte gauges
//! (`pqs_resident_bytes`, `pqs_memory_budget_bytes`), a
//! `pqs_latency_us` summary, one `pqs_trace_stage_us` histogram per
//! span stage (labeled `stage="parse"`…`"respond"`), and the live
//! accumulator telemetry as per-model per-layer gauges:
//! `pqs_headroom_planned_bits`, `pqs_headroom_max_required_bits`,
//! `pqs_headroom_min_bits` (alert when it approaches zero — some dot
//! product came within that many bits of its planned accumulator
//! width), and `pqs_headroom_{dots,overflow_dots,near_saturation_dots}_total`,
//! all labeled `{model=...,layer=...}`.
//!
//! ## `GET /healthz` vs `GET /readyz`
//!
//! Two probes with different questions:
//!
//! * **`/healthz` — liveness.** "Is the process alive?" Always `200`
//!   `{"status":"ok"}` while the front-end runs — even mid-drain, even
//!   with every model broken. Restart-deciders point here: flapping it
//!   on transient trouble turns a degraded fleet into a crash loop.
//! * **`/readyz` — readiness.** "Should NEW traffic come here?" `200`
//!   only when every gate holds, else `503` + `Retry-After: 1`; the
//!   JSON body always reports the individual gates
//!   (`ready`/`draining`/`default_model_ok`/`queue_len`/`queue_cap`):
//!   1. not draining — [`HttpServer::set_draining`] (and shutdown,
//!      which calls it first) flips this *before* any connection
//!      closes, so a load balancer stops routing while in-flight
//!      requests still finish;
//!   2. the default model is serviceable — neither quarantined nor
//!      behind an Open load circuit breaker (unloaded-but-loadable
//!      counts as ready: the first request pays the load);
//!   3. the default model's queue sits below a 90% high-watermark —
//!      readiness sheds load *before* submissions start bouncing 503.
//!
//! ## Failure modes
//!
//! Every failure an operator can see on the wire, with its cause, extra
//! headers, and the counter that records it:
//!
//! | code | cause | headers | counted in |
//! |------|-------|---------|------------|
//! | 400  | malformed HTTP (bad request line, header, `Content-Length`, chunk framing, unsupported transfer coding), invalid JSON, missing/wrong-size `image`, non-string `model`, malformed `acc_bits` (non-positive, non-integer, or given together with `operating_point`), an `acc_bits` below the plan's safe minimum, or an `acc_bits` override on a plan-free model | — | per-model `errors` (JSON-level only; protocol 400s never reach a queue) |
//! | 404  | unknown path, or `model` names an unregistered model (body lists the registered fleet) | — | `router.unknown_model` |
//! | 405  | wrong method on a known path | `Allow: GET, HEAD` or `Allow: POST` | — |
//! | 408  | a partial request stalled past the keep-alive timeout, or a whole request failed to arrive within it | — | `http.read_timeouts` |
//! | 413  | head, declared body, or decoded chunked body over the configured limits | — | — |
//! | 500  | engine failure on the batch the request rode in — including a **worker panic**, which is caught per batch (`catch_unwind`): every rider is answered, the engine is rebuilt, the worker survives — or a registered model's load failed (missing file, injected fault, over the `--max-bytes` budget) | — | per-model `errors`; panics also in per-model `panics` |
//! | 503  | **queue full** (target model's queue, classify worker backlog, connection backlog / `max_connections` cap) — transient, retry | `Retry-After: 1` | `http.shed` per reason: `shed_queue_full` / `shed_max_connections` |
//! | 503  | **breaker open**: the model's recent loads kept failing; requests fast-fail without touching the source until the backoff elapses | `Retry-After:` ceil of the remaining backoff | `router.breaker_fast_fails`, per-model `health.fast_fails` |
//! | 503  | **quarantined**: the model failed an integrity check (checksum mismatch, plan/graph inconsistency); only an explicit reload ends it | — (no `Retry-After`: waiting cannot fix corrupt bytes) | `router.quarantined`, per-model `health` |
//! | 503  | shutting down / draining | — | `http.shed_draining` |
//! | 504  | per-request deadline expired in queue, or the response-wait backstop fired | `Retry-After: 1` | per-model `expired` |
//!
//! All error bodies are `{"error": "<message>"}`. Protocol-level errors
//! (400/413/408) close the connection; semantic errors (404/405 and the
//! JSON-level 400s) keep it open per the usual keep-alive rules.

#[cfg(target_os = "linux")]
mod event_loop;
pub mod parser;
pub mod server;

pub use parser::{parse_request, Limits, ParseError, Request, Version};
pub use server::{FrontendReport, HttpConfig, HttpMetrics, HttpServer};
