//! HTTP/1.1 front-end over the multi-model [`Router`].
//!
//! Plain `std::net` blocking I/O: a nonblocking `TcpListener` accept loop
//! feeds accepted sockets into a bounded [`WorkerPool`] (the connection
//! pool); each handler thread runs the keep-alive read loop, feeding bytes
//! into the incremental parser and answering every complete request. When
//! the pool and its backlog are saturated the accept loop sheds the
//! connection with `503` instead of queueing without bound.
//!
//! Requests are routed by the optional `"model"` field of
//! `POST /v1/classify`; `GET /v1/models` lists the registered fleet and
//! `GET /v1/metrics` nests per-model serving metrics under router- and
//! connection-level counters. See the module docs in `crate::http` for
//! the wire protocol.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::{
    ClassifyRequest, LatencySummary, ModelStatus, RouteError, Router, RouterMetrics, ServeError,
    ServeSummary, SubmitError,
};
use crate::plan::PlanSummary;
use crate::util::json::{self, Json};
use crate::util::pool::{self, WorkerPool};

use super::parser::{self, Limits, Request};

/// Granularity of the connection read loop: how often a blocked read wakes
/// up to check the stop flag and the idle clock.
const READ_TICK: Duration = Duration::from_millis(25);

/// HTTP front-end tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct HttpConfig {
    /// connection-handler threads (the bounded connection pool)
    pub conn_threads: usize,
    /// accepted connections that may wait for a free handler before the
    /// accept loop starts shedding with 503
    pub conn_backlog: usize,
    /// idle keep-alive connections are closed after this long with no
    /// request bytes, and a single request must arrive *completely*
    /// within this budget of its first byte (hard cap, regardless of
    /// drip-feed progress — the anti-slowloris guarantee); stalled or
    /// over-budget partial requests get 408
    pub keep_alive_timeout: Duration,
    /// parser limits (head size, header count, body size)
    pub limits: Limits,
    /// hard cap on waiting for the engine's answer to one request; the
    /// per-request deadline usually fires long before this backstop
    pub response_timeout: Duration,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            conn_threads: pool::default_threads().clamp(2, 8),
            conn_backlog: 64,
            keep_alive_timeout: Duration::from_secs(5),
            limits: Limits::default(),
            response_timeout: Duration::from_secs(30),
        }
    }
}

/// Per-connection counters of the front-end itself (the coordinator's
/// [`crate::coordinator::ServeMetrics`] only see requests that reached a
/// model queue).
/// Exported as the `http` section of `GET /v1/metrics`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HttpMetrics {
    /// connections handed to the connection pool
    pub accepted: u64,
    /// connections shed with 503 because the pool + backlog were saturated
    pub shed: u64,
    /// requests answered 408 because a partial request stalled or overran
    /// the keep-alive budget
    pub read_timeouts: u64,
}

#[derive(Default)]
struct HttpCounters {
    accepted: AtomicU64,
    shed: AtomicU64,
    read_timeouts: AtomicU64,
}

impl HttpCounters {
    fn snapshot(&self) -> HttpMetrics {
        HttpMetrics {
            accepted: self.accepted.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            read_timeouts: self.read_timeouts.load(Ordering::Relaxed),
        }
    }
}

/// Everything [`HttpServer::shutdown`] has to say: the router's lifetime
/// metrics (per-model sections included) plus the front-end's own
/// connection counters.
#[derive(Clone, Debug, Default)]
pub struct FrontendReport {
    pub router: RouterMetrics,
    pub http: HttpMetrics,
}

impl FrontendReport {
    pub fn print(&self) {
        self.router.print();
        println!(
            "http: accepted={} shed={} read_timeouts={}",
            self.http.accepted, self.http.shed, self.http.read_timeouts
        );
    }
}

struct Ctx {
    router: Router,
    cfg: HttpConfig,
    next_id: AtomicU64,
    stop: Arc<AtomicBool>,
    http: HttpCounters,
}

/// The HTTP/1.1 serving front-end. Owns the [`Router`] it forwards
/// classification requests into; [`HttpServer::shutdown`] drains the
/// connection pool, then every model server, and returns the final
/// [`FrontendReport`].
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<WorkerPool<TcpStream>>>,
    ctx: Option<Arc<Ctx>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and serve
    /// requests into `router`.
    pub fn start(router: Router, addr: &str, cfg: HttpConfig) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let ctx = Arc::new(Ctx {
            router,
            cfg,
            next_id: AtomicU64::new(1),
            stop: Arc::clone(&stop),
            http: HttpCounters::default(),
        });

        let hctx = Arc::clone(&ctx);
        let conn_pool = WorkerPool::new(
            cfg.conn_threads.max(1),
            cfg.conn_backlog.max(1),
            move |stream: TcpStream| handle_connection(&hctx, stream),
        );

        // the accept thread owns the pool and hands it back on exit so
        // shutdown can drain it after joining the loop
        let astop = Arc::clone(&stop);
        let actx = Arc::clone(&ctx);
        let accept = std::thread::spawn(move || {
            let mut accept_err_reported = false;
            loop {
                if astop.load(Ordering::Acquire) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        // counted BEFORE dispatch: a handler can finish a
                        // whole request round-trip before this thread runs
                        // again, and that response must already see itself
                        // in `accepted` (shedding takes the count back)
                        actx.http.accepted.fetch_add(1, Ordering::Relaxed);
                        if let Err(shed) = conn_pool.try_dispatch(stream) {
                            actx.http.accepted.fetch_sub(1, Ordering::Relaxed);
                            actx.http.shed.fetch_add(1, Ordering::Relaxed);
                            shed_connection(shed);
                        }
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(e) => {
                        // real accept failure (e.g. fd exhaustion): surface
                        // it once instead of spinning silently, and back
                        // off harder than the poll tick
                        if !accept_err_reported {
                            accept_err_reported = true;
                            eprintln!("http accept error (backing off): {e}");
                        }
                        std::thread::sleep(Duration::from_millis(50));
                    }
                }
            }
            conn_pool
        });

        Ok(HttpServer { addr: local, stop, accept: Some(accept), ctx: Some(ctx) })
    }

    /// The bound address (useful with an ephemeral `:0` bind).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Snapshot of the router's metrics (per-model sections included).
    pub fn metrics(&self) -> RouterMetrics {
        match &self.ctx {
            Some(ctx) => ctx.router.metrics(),
            None => RouterMetrics::default(),
        }
    }

    /// Snapshot of the front-end's own connection counters.
    pub fn http_metrics(&self) -> HttpMetrics {
        match &self.ctx {
            Some(ctx) => ctx.http.snapshot(),
            None => HttpMetrics::default(),
        }
    }

    /// Stop accepting connections, drain the connection pool, shut every
    /// model server down (draining their queues), and return the final
    /// report.
    pub fn shutdown(mut self) -> FrontendReport {
        self.stop_and_drain();
        match self.ctx.take().map(Arc::try_unwrap) {
            Some(Ok(ctx)) => {
                let http = ctx.http.snapshot();
                FrontendReport { router: ctx.router.shutdown(), http }
            }
            // a handler leaked its context somehow: best-effort snapshot
            Some(Err(ctx)) => {
                FrontendReport { router: ctx.router.metrics(), http: ctx.http.snapshot() }
            }
            None => FrontendReport::default(),
        }
    }

    fn stop_and_drain(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            if let Ok(conn_pool) = h.join() {
                conn_pool.shutdown();
            }
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop_and_drain();
    }
}

// ---- connection handling --------------------------------------------------

/// Best-effort 503 for a connection the saturated pool + backlog cannot
/// take. Clears any inherited O_NONBLOCK and bounds the write so a dead
/// peer cannot stall the accept loop.
fn shed_connection(mut stream: TcpStream) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_write_timeout(Some(Duration::from_millis(50)));
    let body = json::obj(vec![("error", json::s("connection backlog full"))]).to_string();
    let _ = stream.write_all(&response_bytes(503, &[], &body, false));
    let _ = stream.shutdown(std::net::Shutdown::Write);
}

fn handle_connection(ctx: &Ctx, mut stream: TcpStream) {
    // accepted sockets can inherit the listener's nonblocking flag on some
    // platforms; handlers use plain blocking reads with a short timeout
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(READ_TICK)).is_err() {
        return;
    }
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 8192];
    let mut idle = Duration::ZERO;
    // first byte of the currently-buffered partial request: a request must
    // complete within keep_alive_timeout of it, so a slow-drip client
    // (one byte per tick) cannot pin a pool worker indefinitely
    let mut partial_since: Option<std::time::Instant> = None;
    loop {
        // answer every complete pipelined request already buffered
        loop {
            let step = match parser::parse_request(&buf, &ctx.cfg.limits) {
                Ok(Some((req, consumed))) => {
                    let (resp, keep) = route(ctx, &req);
                    Some((resp, keep, consumed))
                }
                Ok(None) => None,
                Err(e) => {
                    let body = json::obj(vec![("error", json::s(e.message()))]).to_string();
                    let _ = stream.write_all(&response_bytes(e.status(), &[], &body, false));
                    return;
                }
            };
            match step {
                Some((resp, keep, consumed)) => {
                    if stream.write_all(&resp).is_err() {
                        return;
                    }
                    buf.drain(..consumed);
                    idle = Duration::ZERO;
                    partial_since = None;
                    if !keep {
                        return;
                    }
                }
                None => break,
            }
        }
        if buf.is_empty() {
            partial_since = None;
        } else if let Some(t0) = partial_since {
            if t0.elapsed() >= ctx.cfg.keep_alive_timeout {
                ctx.http.read_timeouts.fetch_add(1, Ordering::Relaxed);
                let body = json::obj(vec![("error", json::s("request incomplete"))]).to_string();
                let _ = stream.write_all(&response_bytes(408, &[], &body, false));
                return;
            }
        } else {
            partial_since = Some(std::time::Instant::now());
        }
        if ctx.stop.load(Ordering::Acquire) {
            return;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return, // peer closed
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                idle = Duration::ZERO;
            }
            Err(ref e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                idle += READ_TICK;
                if idle >= ctx.cfg.keep_alive_timeout {
                    if !buf.is_empty() {
                        // a partial request stalled mid-flight
                        ctx.http.read_timeouts.fetch_add(1, Ordering::Relaxed);
                        let body =
                            json::obj(vec![("error", json::s("request incomplete"))]).to_string();
                        let _ = stream.write_all(&response_bytes(408, &[], &body, false));
                    }
                    return;
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

/// Dispatch one parsed request; returns the full response bytes and
/// whether to keep the connection open.
fn route(ctx: &Ctx, req: &Request<'_>) -> (Vec<u8>, bool) {
    let keep = req.keep_alive() && !ctx.stop.load(Ordering::Acquire);
    match (req.method, req.path()) {
        ("GET", "/healthz") => {
            let body = json::obj(vec![("status", json::s("ok"))]).to_string();
            (response_bytes(200, &[], &body, keep), keep)
        }
        ("GET", "/v1/metrics") => {
            let body = metrics_json(&ctx.router.metrics(), &ctx.http.snapshot());
            (response_bytes(200, &[], &body, keep), keep)
        }
        ("GET", "/v1/models") => {
            let body = models_json(ctx.router.default_model(), &ctx.router.models());
            (response_bytes(200, &[], &body, keep), keep)
        }
        ("POST", "/v1/classify") => classify(ctx, req, keep),
        (_, "/healthz") | (_, "/v1/metrics") | (_, "/v1/models") => {
            method_not_allowed("GET", keep)
        }
        (_, "/v1/classify") => method_not_allowed("POST", keep),
        _ => (error_response(404, "no such endpoint", keep), keep),
    }
}

fn classify(ctx: &Ctx, req: &Request<'_>, keep: bool) -> (Vec<u8>, bool) {
    let payload = match Json::parse_bytes(&req.body) {
        Ok(j) => j,
        Err(e) => return (error_response(400, &format!("invalid json body: {e}"), keep), keep),
    };
    // decode the pixels straight into the f32 batch buffer (one
    // allocation, not an intermediate Vec<f64>)
    let image: Vec<f32> = match payload.get("image").and_then(Json::as_arr) {
        Some(arr) => {
            let mut img = Vec::with_capacity(arr.len());
            for v in arr {
                match v.as_f64() {
                    Some(x) => img.push(x as f32),
                    None => {
                        return (
                            error_response(400, "\"image\" must contain only numbers", keep),
                            keep,
                        )
                    }
                }
            }
            img
        }
        None => {
            return (
                error_response(400, "body must carry a numeric \"image\" array", keep),
                keep,
            )
        }
    };
    // id is echoed back verbatim, so a present-but-invalid id is a 400,
    // never silently replaced; an absent id is auto-assigned
    let id = match payload.get("id") {
        None => ctx.next_id.fetch_add(1, Ordering::Relaxed),
        Some(v) => match v.as_i64().and_then(|i| u64::try_from(i).ok()) {
            Some(i) => i,
            None => {
                return (
                    error_response(400, "\"id\" must be a non-negative integer", keep),
                    keep,
                )
            }
        },
    };
    // route target: a present-but-non-string model is a 400 (a typo must
    // not silently fall through to the default model); absent = default
    let model: Option<String> = match payload.get("model") {
        None => None,
        Some(v) => match v.as_str() {
            Some(s) => Some(s.to_string()),
            None => return (error_response(400, "\"model\" must be a string", keep), keep),
        },
    };
    // clamp to [0, 1 day] and reject non-finite values so a hostile
    // payload can never panic Duration::from_secs_f64 (which would kill a
    // pool worker)
    let deadline = payload
        .get("deadline_ms")
        .and_then(Json::as_f64)
        .filter(|ms| ms.is_finite())
        .map(|ms| Duration::from_secs_f64(ms.clamp(0.0, 86_400_000.0) / 1e3));
    // per-request accumulator operating point ("operating_point" is an
    // accepted alias). Only the field's shape is checked here; the width
    // itself is validated against the routed model's embedded plan by its
    // server, which answers an under-bound or plan-free override with
    // BadRequest → 400
    let acc_field = match (payload.get("acc_bits"), payload.get("operating_point")) {
        (Some(_), Some(_)) => {
            return (
                error_response(400, "use \"acc_bits\" or \"operating_point\", not both", keep),
                keep,
            )
        }
        (v, None) | (None, v) => v,
    };
    let acc_bits: Option<u32> = match acc_field {
        None => None,
        Some(v) => match v.as_i64().and_then(|i| u32::try_from(i).ok()).filter(|&b| b > 0) {
            Some(b) => Some(b),
            None => {
                return (
                    error_response(400, "\"acc_bits\" must be a positive integer", keep),
                    keep,
                )
            }
        },
    };

    let request = ClassifyRequest { id, model, image, deadline, acc_bits };
    let pending = match ctx.router.try_submit(request) {
        Ok(p) => p,
        Err(RouteError::UnknownModel(msg)) => return (error_response(404, &msg, keep), keep),
        Err(RouteError::LoadFailed(msg)) => return (error_response(500, &msg, keep), keep),
        Err(RouteError::Rejected(e)) => {
            // a closing server also closes the connection; a full queue is
            // transient, so the connection stays usable for a retry
            let keep = keep && !matches!(e, SubmitError::Closed(_));
            let msg = RouteError::Rejected(e).to_string();
            return (error_response(503, &msg, keep), keep);
        }
    };
    let resp = match pending.wait_timeout(ctx.cfg.response_timeout) {
        Some(r) => r,
        None => {
            return (error_response(504, "timed out waiting for the engine", keep), keep)
        }
    };
    match resp.result {
        Ok(class) => {
            let body = json::obj(vec![
                ("id", json::num(resp.id as f64)),
                ("class", json::num(class as f64)),
                ("queue_us", json::num(resp.queue_us)),
                ("compute_us", json::num(resp.compute_us)),
                ("latency_us", json::num(resp.latency_us)),
                ("batch_size", json::num(resp.batch_size as f64)),
            ])
            .to_string();
            (response_bytes(200, &[], &body, keep), keep)
        }
        Err(ServeError::Expired { waited_us }) => {
            let body = json::obj(vec![
                ("error", json::s("deadline exceeded before the engine picked it up")),
                ("id", json::num(resp.id as f64)),
                ("waited_us", json::num(waited_us as f64)),
            ])
            .to_string();
            (response_bytes(504, &[], &body, keep), keep)
        }
        Err(ServeError::BadRequest(m)) => (error_response(400, &m, keep), keep),
        Err(ServeError::Internal(m)) => (error_response(500, &m, keep), keep),
    }
}

fn method_not_allowed(allow: &str, keep: bool) -> (Vec<u8>, bool) {
    let body = json::obj(vec![("error", json::s("method not allowed"))]).to_string();
    (response_bytes(405, &[("Allow", allow)], &body, keep), keep)
}

fn error_response(status: u16, message: &str, keep: bool) -> Vec<u8> {
    let body = json::obj(vec![("error", json::s(message))]).to_string();
    response_bytes(status, &[], &body, keep)
}

fn status_reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Content Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Serialize one response. `body` must already be JSON text.
fn response_bytes(status: u16, extra: &[(&str, &str)], body: &str, keep: bool) -> Vec<u8> {
    let mut out = String::with_capacity(body.len() + 128);
    out.push_str("HTTP/1.1 ");
    out.push_str(&status.to_string());
    out.push(' ');
    out.push_str(status_reason(status));
    out.push_str("\r\nContent-Type: application/json\r\nContent-Length: ");
    out.push_str(&body.len().to_string());
    out.push_str("\r\nConnection: ");
    out.push_str(if keep { "keep-alive" } else { "close" });
    out.push_str("\r\n");
    for (k, v) in extra {
        out.push_str(k);
        out.push_str(": ");
        out.push_str(v);
        out.push_str("\r\n");
    }
    out.push_str("\r\n");
    out.push_str(body);
    out.into_bytes()
}

// ---- JSON serialization of the metrics surfaces ---------------------------

fn summary_json(r: &LatencySummary) -> Json {
    json::obj(vec![
        ("count", json::num(r.count as f64)),
        ("mean_us", json::num(r.mean_us)),
        ("p50_us", json::num(r.p50_us)),
        ("p95_us", json::num(r.p95_us)),
        ("p99_us", json::num(r.p99_us)),
        ("max_us", json::num(r.max_us)),
    ])
}

fn plan_json(plan: &Option<PlanSummary>) -> Json {
    match plan {
        Some(p) => json::obj(vec![
            ("planner", json::s(p.planner.name())),
            ("layers", json::num(p.layers as f64)),
            ("min_bits", json::num(p.min_bits as f64)),
            ("max_bits", json::num(p.max_bits as f64)),
            ("mean_bits", json::num(p.mean_bits)),
        ]),
        None => Json::Null,
    }
}

fn serve_metrics_json(m: &ServeSummary) -> Json {
    json::obj(vec![
        ("requests", json::num(m.requests as f64)),
        ("errors", json::num(m.errors as f64)),
        ("expired", json::num(m.expired as f64)),
        ("batches", json::num(m.batches as f64)),
        ("mean_batch", json::num(m.mean_batch)),
        ("throughput_rps", json::num(m.throughput_rps)),
        ("wall_s", json::num(m.wall_s)),
        ("latency", summary_json(&m.latency)),
        ("queue", summary_json(&m.queue)),
        ("compute", summary_json(&m.compute)),
    ])
}

fn shape_json(shape: &Option<Vec<usize>>) -> Json {
    match shape {
        Some(s) => Json::Arr(s.iter().map(|&d| json::num(d as f64)).collect()),
        None => Json::Null,
    }
}

/// The `GET /v1/metrics` document: aggregate counters at the top level
/// (old single-model clients keep working), then `router` counters,
/// per-model sections under `models`, the front-end's `http` counters,
/// and the shared compute pool (`null` when engines run single-threaded).
fn metrics_json(rm: &RouterMetrics, hm: &HttpMetrics) -> String {
    let agg = rm.aggregate();
    let models = Json::Obj(
        rm.models
            .iter()
            .map(|m| {
                let mut obj = match serve_metrics_json(&m.metrics) {
                    Json::Obj(o) => o,
                    _ => unreachable!("serve_metrics_json returns an object"),
                };
                obj.insert("loaded".into(), Json::Bool(m.loaded));
                obj.insert("default".into(), Json::Bool(m.default));
                obj.insert("input_shape".into(), shape_json(&m.input_shape));
                obj.insert("plan".into(), plan_json(&m.plan));
                (m.name.clone(), Json::Obj(obj))
            })
            .collect(),
    );
    // pool utilization of the shared intra-forward compute pool; `null`
    // when every engine runs single-threaded
    let pool = match &rm.pool {
        Some(p) => json::obj(vec![
            ("threads", json::num(p.threads as f64)),
            ("busy", json::num(p.busy as f64)),
            ("jobs", json::num(p.jobs as f64)),
            ("inline_jobs", json::num(p.inline_jobs as f64)),
            ("chunks", json::num(p.chunks as f64)),
        ]),
        None => Json::Null,
    };
    json::obj(vec![
        ("requests", json::num(agg.requests as f64)),
        ("errors", json::num(agg.errors as f64)),
        ("expired", json::num(agg.expired as f64)),
        ("batches", json::num(agg.batches as f64)),
        ("mean_batch", json::num(agg.mean_batch)),
        ("throughput_rps", json::num(agg.throughput_rps)),
        ("wall_s", json::num(agg.wall_s)),
        ("latency", summary_json(&agg.latency)),
        ("queue", summary_json(&agg.queue)),
        ("compute", summary_json(&agg.compute)),
        (
            "router",
            json::obj(vec![
                ("routed", json::num(rm.routed as f64)),
                ("unknown_model", json::num(rm.unknown_model as f64)),
                ("loads", json::num(rm.loads as f64)),
                ("evictions", json::num(rm.evictions as f64)),
                ("resident_bytes", json::num(rm.resident_bytes as f64)),
                ("budget", json::num(rm.budget as f64)),
                ("dedup_hits", json::num(rm.dedup_hits as f64)),
                ("load_latency", summary_json(&rm.load_latency)),
            ]),
        ),
        ("models", models),
        (
            "http",
            json::obj(vec![
                ("accepted", json::num(hm.accepted as f64)),
                ("shed", json::num(hm.shed as f64)),
                ("read_timeouts", json::num(hm.read_timeouts as f64)),
            ]),
        ),
        ("pool", pool),
    ])
    .to_string()
}

/// The `GET /v1/models` document: the default route and one row per
/// registered model (load state, input shape, embedded accumulator-plan
/// summary, per-model metrics).
fn models_json(default: &str, models: &[ModelStatus]) -> String {
    let rows: Vec<Json> = models
        .iter()
        .map(|m| {
            json::obj(vec![
                ("name", json::s(&m.name)),
                ("default", Json::Bool(m.default)),
                ("loaded", Json::Bool(m.loaded)),
                ("input_shape", shape_json(&m.input_shape)),
                ("plan", plan_json(&m.plan)),
                (
                    "resident_bytes",
                    m.resident_bytes.map_or(Json::Null, |b| json::num(b as f64)),
                ),
                ("metrics", serve_metrics_json(&m.metrics)),
            ])
        })
        .collect();
    json::obj(vec![("default", json::s(default)), ("models", Json::Arr(rows))]).to_string()
}
