//! HTTP/1.1 front-end over [`crate::coordinator::Server`].
//!
//! Plain `std::net` blocking I/O: a nonblocking `TcpListener` accept loop
//! feeds accepted sockets into a bounded [`WorkerPool`] (the connection
//! pool); each handler thread runs the keep-alive read loop, feeding bytes
//! into the incremental parser and answering every complete request. When
//! the pool and its backlog are saturated the accept loop sheds the
//! connection with `503` instead of queueing without bound.
//!
//! See the module docs in `crate::http` for the wire protocol.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::{LatencyRecorder, ServeError, ServeMetrics, Server, SubmitError};
use crate::util::json::{self, Json};
use crate::util::pool::{self, WorkerPool};

use super::parser::{self, Limits, Request};

/// Granularity of the connection read loop: how often a blocked read wakes
/// up to check the stop flag and the idle clock.
const READ_TICK: Duration = Duration::from_millis(25);

/// HTTP front-end tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct HttpConfig {
    /// connection-handler threads (the bounded connection pool)
    pub conn_threads: usize,
    /// accepted connections that may wait for a free handler before the
    /// accept loop starts shedding with 503
    pub conn_backlog: usize,
    /// idle keep-alive connections are closed after this long with no
    /// request bytes, and a single request must arrive *completely*
    /// within this budget of its first byte (hard cap, regardless of
    /// drip-feed progress — the anti-slowloris guarantee); stalled or
    /// over-budget partial requests get 408
    pub keep_alive_timeout: Duration,
    /// parser limits (head size, header count, body size)
    pub limits: Limits,
    /// hard cap on waiting for the engine's answer to one request; the
    /// per-request deadline usually fires long before this backstop
    pub response_timeout: Duration,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            conn_threads: pool::default_threads().clamp(2, 8),
            conn_backlog: 64,
            keep_alive_timeout: Duration::from_secs(5),
            limits: Limits::default(),
            response_timeout: Duration::from_secs(30),
        }
    }
}

struct Ctx {
    srv: Server,
    cfg: HttpConfig,
    next_id: AtomicU64,
    stop: Arc<AtomicBool>,
}

/// The HTTP/1.1 serving front-end. Owns the coordinator [`Server`] it
/// forwards classification requests into; [`HttpServer::shutdown`] drains
/// the connection pool, then the coordinator, and returns final metrics.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<WorkerPool<TcpStream>>>,
    ctx: Option<Arc<Ctx>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and serve
    /// requests into `srv`.
    pub fn start(srv: Server, addr: &str, cfg: HttpConfig) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let ctx =
            Arc::new(Ctx { srv, cfg, next_id: AtomicU64::new(1), stop: Arc::clone(&stop) });

        let hctx = Arc::clone(&ctx);
        let conn_pool = WorkerPool::new(
            cfg.conn_threads.max(1),
            cfg.conn_backlog.max(1),
            move |stream: TcpStream| handle_connection(&hctx, stream),
        );

        // the accept thread owns the pool and hands it back on exit so
        // shutdown can drain it after joining the loop
        let astop = Arc::clone(&stop);
        let accept = std::thread::spawn(move || {
            let mut accept_err_reported = false;
            loop {
                if astop.load(Ordering::Acquire) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        if let Err(mut shed) = conn_pool.try_dispatch(stream) {
                            // connection pool + backlog saturated: best-effort
                            // 503. Clear any inherited O_NONBLOCK and bound the
                            // write so a dead peer cannot stall the accept loop.
                            let _ = shed.set_nonblocking(false);
                            let _ = shed.set_write_timeout(Some(Duration::from_millis(50)));
                            let body =
                                json::obj(vec![("error", json::s("connection backlog full"))])
                                    .to_string();
                            let _ = shed.write_all(&response_bytes(503, &[], &body, false));
                            let _ = shed.shutdown(std::net::Shutdown::Write);
                        }
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(e) => {
                        // real accept failure (e.g. fd exhaustion): surface
                        // it once instead of spinning silently, and back
                        // off harder than the poll tick
                        if !accept_err_reported {
                            accept_err_reported = true;
                            eprintln!("http accept error (backing off): {e}");
                        }
                        std::thread::sleep(Duration::from_millis(50));
                    }
                }
            }
            conn_pool
        });

        Ok(HttpServer { addr: local, stop, accept: Some(accept), ctx: Some(ctx) })
    }

    /// The bound address (useful with an ephemeral `:0` bind).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Snapshot of the coordinator's serving metrics.
    pub fn metrics(&self) -> ServeMetrics {
        match &self.ctx {
            Some(ctx) => ctx.srv.metrics(),
            None => ServeMetrics::default(),
        }
    }

    /// Stop accepting connections, drain the connection pool, shut the
    /// coordinator down (draining its queue), and return final metrics.
    pub fn shutdown(mut self) -> ServeMetrics {
        self.stop_and_drain();
        match self.ctx.take().map(Arc::try_unwrap) {
            Some(Ok(ctx)) => ctx.srv.shutdown(),
            // a handler leaked its context somehow: best-effort snapshot
            Some(Err(ctx)) => ctx.srv.metrics(),
            None => ServeMetrics::default(),
        }
    }

    fn stop_and_drain(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            if let Ok(conn_pool) = h.join() {
                conn_pool.shutdown();
            }
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop_and_drain();
    }
}

// ---- connection handling --------------------------------------------------

fn handle_connection(ctx: &Ctx, mut stream: TcpStream) {
    // accepted sockets can inherit the listener's nonblocking flag on some
    // platforms; handlers use plain blocking reads with a short timeout
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(READ_TICK)).is_err() {
        return;
    }
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 8192];
    let mut idle = Duration::ZERO;
    // first byte of the currently-buffered partial request: a request must
    // complete within keep_alive_timeout of it, so a slow-drip client
    // (one byte per tick) cannot pin a pool worker indefinitely
    let mut partial_since: Option<std::time::Instant> = None;
    loop {
        // answer every complete pipelined request already buffered
        loop {
            let step = match parser::parse_request(&buf, &ctx.cfg.limits) {
                Ok(Some((req, consumed))) => {
                    let (resp, keep) = route(ctx, &req);
                    Some((resp, keep, consumed))
                }
                Ok(None) => None,
                Err(e) => {
                    let body = json::obj(vec![("error", json::s(e.message()))]).to_string();
                    let _ = stream.write_all(&response_bytes(e.status(), &[], &body, false));
                    return;
                }
            };
            match step {
                Some((resp, keep, consumed)) => {
                    if stream.write_all(&resp).is_err() {
                        return;
                    }
                    buf.drain(..consumed);
                    idle = Duration::ZERO;
                    partial_since = None;
                    if !keep {
                        return;
                    }
                }
                None => break,
            }
        }
        if buf.is_empty() {
            partial_since = None;
        } else if let Some(t0) = partial_since {
            if t0.elapsed() >= ctx.cfg.keep_alive_timeout {
                let body = json::obj(vec![("error", json::s("request incomplete"))]).to_string();
                let _ = stream.write_all(&response_bytes(408, &[], &body, false));
                return;
            }
        } else {
            partial_since = Some(std::time::Instant::now());
        }
        if ctx.stop.load(Ordering::Acquire) {
            return;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return, // peer closed
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                idle = Duration::ZERO;
            }
            Err(ref e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                idle += READ_TICK;
                if idle >= ctx.cfg.keep_alive_timeout {
                    if !buf.is_empty() {
                        // a partial request stalled mid-flight
                        let body =
                            json::obj(vec![("error", json::s("request incomplete"))]).to_string();
                        let _ = stream.write_all(&response_bytes(408, &[], &body, false));
                    }
                    return;
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

/// Dispatch one parsed request; returns the full response bytes and
/// whether to keep the connection open.
fn route(ctx: &Ctx, req: &Request<'_>) -> (Vec<u8>, bool) {
    let keep = req.keep_alive() && !ctx.stop.load(Ordering::Acquire);
    match (req.method, req.path()) {
        ("GET", "/healthz") => {
            let body = json::obj(vec![("status", json::s("ok"))]).to_string();
            (response_bytes(200, &[], &body, keep), keep)
        }
        ("GET", "/v1/metrics") => {
            let body = metrics_json(&ctx.srv.metrics());
            (response_bytes(200, &[], &body, keep), keep)
        }
        ("POST", "/v1/classify") => classify(ctx, req, keep),
        (_, "/healthz") | (_, "/v1/metrics") => method_not_allowed("GET", keep),
        (_, "/v1/classify") => method_not_allowed("POST", keep),
        _ => (error_response(404, "no such endpoint", keep), keep),
    }
}

fn classify(ctx: &Ctx, req: &Request<'_>, keep: bool) -> (Vec<u8>, bool) {
    let payload = match Json::parse_bytes(req.body) {
        Ok(j) => j,
        Err(e) => return (error_response(400, &format!("invalid json body: {e}"), keep), keep),
    };
    // decode the pixels straight into the f32 batch buffer (one
    // allocation, not an intermediate Vec<f64>)
    let image: Vec<f32> = match payload.get("image").and_then(Json::as_arr) {
        Some(arr) => {
            let mut img = Vec::with_capacity(arr.len());
            for v in arr {
                match v.as_f64() {
                    Some(x) => img.push(x as f32),
                    None => {
                        return (
                            error_response(400, "\"image\" must contain only numbers", keep),
                            keep,
                        )
                    }
                }
            }
            img
        }
        None => {
            return (
                error_response(400, "body must carry a numeric \"image\" array", keep),
                keep,
            )
        }
    };
    // id is echoed back verbatim, so a present-but-invalid id is a 400,
    // never silently replaced; an absent id is auto-assigned
    let id = match payload.get("id") {
        None => ctx.next_id.fetch_add(1, Ordering::Relaxed),
        Some(v) => match v.as_i64().and_then(|i| u64::try_from(i).ok()) {
            Some(i) => i,
            None => {
                return (
                    error_response(400, "\"id\" must be a non-negative integer", keep),
                    keep,
                )
            }
        },
    };
    // clamp to [0, 1 day] and reject non-finite values so a hostile
    // payload can never panic Duration::from_secs_f64 (which would kill a
    // pool worker)
    let deadline = payload
        .get("deadline_ms")
        .and_then(Json::as_f64)
        .filter(|ms| ms.is_finite())
        .map(|ms| Duration::from_secs_f64(ms.clamp(0.0, 86_400_000.0) / 1e3));

    let pending = match ctx.srv.try_submit(id, image, deadline) {
        Ok(p) => p,
        Err(SubmitError::Full(_)) => {
            return (error_response(503, "request queue is full; retry later", keep), keep)
        }
        Err(SubmitError::Closed(_)) => {
            return (error_response(503, "server is shutting down", false), false)
        }
    };
    let resp = match pending.wait_timeout(ctx.cfg.response_timeout) {
        Some(r) => r,
        None => {
            return (error_response(504, "timed out waiting for the engine", keep), keep)
        }
    };
    match resp.result {
        Ok(class) => {
            let body = json::obj(vec![
                ("id", json::num(resp.id as f64)),
                ("class", json::num(class as f64)),
                ("queue_us", json::num(resp.queue_us)),
                ("compute_us", json::num(resp.compute_us)),
                ("latency_us", json::num(resp.latency_us)),
                ("batch_size", json::num(resp.batch_size as f64)),
            ])
            .to_string();
            (response_bytes(200, &[], &body, keep), keep)
        }
        Err(ServeError::Expired { waited_us }) => {
            let body = json::obj(vec![
                ("error", json::s("deadline exceeded before the engine picked it up")),
                ("id", json::num(resp.id as f64)),
                ("waited_us", json::num(waited_us as f64)),
            ])
            .to_string();
            (response_bytes(504, &[], &body, keep), keep)
        }
        Err(ServeError::BadRequest(m)) => (error_response(400, &m, keep), keep),
        Err(ServeError::Internal(m)) => (error_response(500, &m, keep), keep),
    }
}

fn method_not_allowed(allow: &str, keep: bool) -> (Vec<u8>, bool) {
    let body = json::obj(vec![("error", json::s("method not allowed"))]).to_string();
    (response_bytes(405, &[("Allow", allow)], &body, keep), keep)
}

fn error_response(status: u16, message: &str, keep: bool) -> Vec<u8> {
    let body = json::obj(vec![("error", json::s(message))]).to_string();
    response_bytes(status, &[], &body, keep)
}

fn status_reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Content Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Serialize one response. `body` must already be JSON text.
fn response_bytes(status: u16, extra: &[(&str, &str)], body: &str, keep: bool) -> Vec<u8> {
    let mut out = String::with_capacity(body.len() + 128);
    out.push_str("HTTP/1.1 ");
    out.push_str(&status.to_string());
    out.push(' ');
    out.push_str(status_reason(status));
    out.push_str("\r\nContent-Type: application/json\r\nContent-Length: ");
    out.push_str(&body.len().to_string());
    out.push_str("\r\nConnection: ");
    out.push_str(if keep { "keep-alive" } else { "close" });
    out.push_str("\r\n");
    for (k, v) in extra {
        out.push_str(k);
        out.push_str(": ");
        out.push_str(v);
        out.push_str("\r\n");
    }
    out.push_str("\r\n");
    out.push_str(body);
    out.into_bytes()
}

fn metrics_json(m: &ServeMetrics) -> String {
    fn recorder(r: &LatencyRecorder) -> Json {
        json::obj(vec![
            ("count", json::num(r.count() as f64)),
            ("mean_us", json::num(r.mean_us())),
            ("p50_us", json::num(r.p50_us())),
            ("p95_us", json::num(r.p95_us())),
            ("p99_us", json::num(r.p99_us())),
            ("max_us", json::num(r.max_us())),
        ])
    }
    // pool utilization of the shared intra-forward compute pool; `null`
    // when the server runs engines single-threaded
    let pool = match &m.pool {
        Some(p) => json::obj(vec![
            ("threads", json::num(p.threads as f64)),
            ("busy", json::num(p.busy as f64)),
            ("jobs", json::num(p.jobs as f64)),
            ("inline_jobs", json::num(p.inline_jobs as f64)),
            ("chunks", json::num(p.chunks as f64)),
        ]),
        None => Json::Null,
    };
    json::obj(vec![
        ("requests", json::num(m.requests as f64)),
        ("errors", json::num(m.errors as f64)),
        ("expired", json::num(m.expired as f64)),
        ("batches", json::num(m.batches as f64)),
        ("mean_batch", json::num(m.mean_batch)),
        ("throughput_rps", json::num(m.throughput_rps)),
        ("wall_s", json::num(m.wall_s)),
        ("latency", recorder(&m.latency)),
        ("queue", recorder(&m.queue)),
        ("compute", recorder(&m.compute)),
        ("pool", pool),
    ])
    .to_string()
}
