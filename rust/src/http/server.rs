//! HTTP/1.1 front-end over the multi-model [`Router`].
//!
//! Two connection backends share one request/response layer:
//!
//! * **Event loop** (Linux, [`HttpConfig::event_loop`], the default
//!   there): a single readiness-driven thread multiplexes every
//!   connection over `epoll` (`super::event_loop`) — nonblocking sockets,
//!   per-connection state machines, write-interest registration for
//!   partially flushed responses, and a timer wheel for keep-alive /
//!   slow-drip deadlines. Blocking classify work runs on a bounded
//!   [`WorkerPool`] of `conn_threads` workers; fast GET/HEAD endpoints
//!   are answered inline on the loop. Tens of thousands of mostly idle
//!   keep-alive connections cost one thread plus a few hundred bytes
//!   each, bounded by [`HttpConfig::max_connections`] (accepts past the
//!   cap shed with 503).
//!
//! * **Blocking fallback** (every platform): a nonblocking `TcpListener`
//!   accept loop feeds accepted sockets into the bounded [`WorkerPool`]
//!   (the connection pool); each handler thread runs the keep-alive read
//!   loop. When the pool and its backlog are saturated the accept loop
//!   sheds the connection with `503`.
//!
//! Both backends parse with the incremental [`super::parser`], route
//! through [`route_fast`]/[`prepare_classify`]/[`run_classify`], and
//! frame responses with [`encode_reply`] — large bodies stream as
//! `Transfer-Encoding: chunked` to HTTP/1.1 clients past
//! [`HttpConfig::stream_threshold`], byte-identical payload to the
//! buffered path. See the module docs in `crate::http` for the wire
//! protocol.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::{
    ClassifyRequest, LatencySummary, ModelHealth, ModelStatus, RouteError, Router, RouterMetrics,
    ServeError, ServeSummary, SubmitError,
};
use crate::plan::PlanSummary;
use crate::trace::{
    self, PromText, RequestTrace, SpanStages, TraceConfig, TraceSpan, Tracer, MAX_REQUEST_ID_LEN,
};
use crate::util::json::{self, Json};
use crate::util::pool::{self, WorkerPool};

use super::parser::{self, Limits, Request, Version};

/// Granularity of the blocking-backend connection read loop: how often a
/// blocked read wakes up to check the stop flag and the idle deadline.
const READ_TICK: Duration = Duration::from_millis(25);

/// Chunk size used when a response body streams as
/// `Transfer-Encoding: chunked`.
pub(crate) const RESPONSE_CHUNK: usize = 16 * 1024;

/// HTTP front-end tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct HttpConfig {
    /// blocking workers: connection-handler threads under the fallback
    /// backend, classify workers under the event loop
    pub conn_threads: usize,
    /// items that may queue for a free worker before shedding with 503
    /// (waiting connections on the fallback path, waiting classify jobs
    /// on the event loop)
    pub conn_backlog: usize,
    /// idle keep-alive connections are closed after this long with no
    /// request bytes, and a single request must arrive *completely*
    /// within this budget of its first byte (hard cap, regardless of
    /// drip-feed progress — the anti-slowloris guarantee); stalled or
    /// over-budget partial requests get 408
    pub keep_alive_timeout: Duration,
    /// parser limits (head size, header count, body size)
    pub limits: Limits,
    /// hard cap on waiting for the engine's answer to one request; the
    /// per-request deadline usually fires long before this backstop
    pub response_timeout: Duration,
    /// serve connections from the readiness-driven `epoll` event loop.
    /// Linux only: elsewhere the flag is ignored and the blocking
    /// fallback runs. Defaults on where supported.
    pub event_loop: bool,
    /// hard cap on concurrently open connections under the event loop;
    /// accepts past it are shed with 503 (the blocking backend is bounded
    /// by `conn_threads + conn_backlog` instead)
    pub max_connections: usize,
    /// response bodies larger than this stream as
    /// `Transfer-Encoding: chunked` to HTTP/1.1 clients (HTTP/1.0 and
    /// HEAD responses always use `Content-Length`); payload bytes are
    /// identical either way
    pub stream_threshold: usize,
    /// request tracing: `X-Request-Id` echo, per-request span capture
    /// into the `GET /v1/trace` ring, per-stage latency histograms.
    /// Enabled at sample rate 0 by default — IDs are echoed and stage
    /// histograms recorded, but only error/overflow spans reach the ring
    /// (CLI: `serve-http --trace-sample-rate`).
    pub trace: TraceConfig,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            conn_threads: pool::default_threads().clamp(2, 8),
            conn_backlog: 64,
            keep_alive_timeout: Duration::from_secs(5),
            limits: Limits::default(),
            response_timeout: Duration::from_secs(30),
            event_loop: cfg!(target_os = "linux"),
            max_connections: 16_384,
            stream_threshold: 64 * 1024,
            trace: TraceConfig::default(),
        }
    }
}

/// Per-connection counters of the front-end itself (the coordinator's
/// [`crate::coordinator::ServeMetrics`] only see requests that reached a
/// model queue).
/// Exported as the `http` section of `GET /v1/metrics`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HttpMetrics {
    /// connections handed to a backend (event loop slab or connection
    /// pool)
    pub accepted: u64,
    /// connections/requests shed with 503, every reason summed (equals
    /// `shed_queue_full + shed_max_connections + shed_draining`)
    pub shed: u64,
    /// sheds because a bounded queue was saturated: the connection
    /// pool + backlog (blocking backend) or the classify-worker backlog
    /// (event loop)
    pub shed_queue_full: u64,
    /// sheds because the event loop's `max_connections` cap was hit
    pub shed_max_connections: u64,
    /// sheds because the server was draining (shutdown in progress) when
    /// the work arrived
    pub shed_draining: u64,
    /// requests answered 408 because a partial request stalled or overran
    /// the keep-alive budget
    pub read_timeouts: u64,
}

#[derive(Default)]
pub(crate) struct HttpCounters {
    pub(crate) accepted: AtomicU64,
    pub(crate) shed: AtomicU64,
    pub(crate) shed_queue_full: AtomicU64,
    pub(crate) shed_max_connections: AtomicU64,
    pub(crate) shed_draining: AtomicU64,
    pub(crate) read_timeouts: AtomicU64,
}

/// Shed reasons as they appear in trace events and the Prometheus
/// `reason` label (see [`HttpMetrics`] for what each one counts).
pub(crate) const SHED_QUEUE_FULL: &str = "queue_full";
pub(crate) const SHED_MAX_CONNECTIONS: &str = "max_connections";
pub(crate) const SHED_DRAINING: &str = "draining";

impl HttpCounters {
    pub(crate) fn snapshot(&self) -> HttpMetrics {
        HttpMetrics {
            accepted: self.accepted.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            shed_queue_full: self.shed_queue_full.load(Ordering::Relaxed),
            shed_max_connections: self.shed_max_connections.load(Ordering::Relaxed),
            shed_draining: self.shed_draining.load(Ordering::Relaxed),
            read_timeouts: self.read_timeouts.load(Ordering::Relaxed),
        }
    }

    /// Count one shed under `reason` (the total and the per-reason
    /// counter move together so `shed` always equals the reason sum).
    pub(crate) fn count_shed(&self, reason: &str) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        let per_reason = match reason {
            SHED_QUEUE_FULL => &self.shed_queue_full,
            SHED_MAX_CONNECTIONS => &self.shed_max_connections,
            _ => &self.shed_draining,
        };
        per_reason.fetch_add(1, Ordering::Relaxed);
    }
}

/// Everything [`HttpServer::shutdown`] has to say: the router's lifetime
/// metrics (per-model sections included) plus the front-end's own
/// connection counters.
#[derive(Clone, Debug, Default)]
pub struct FrontendReport {
    pub router: RouterMetrics,
    pub http: HttpMetrics,
}

impl FrontendReport {
    pub fn print(&self) {
        self.router.print();
        println!(
            "http: accepted={} shed={} read_timeouts={}",
            self.http.accepted, self.http.shed, self.http.read_timeouts
        );
    }
}

pub(crate) struct Ctx {
    pub(crate) router: Router,
    pub(crate) cfg: HttpConfig,
    pub(crate) next_id: AtomicU64,
    pub(crate) stop: Arc<AtomicBool>,
    pub(crate) http: HttpCounters,
    /// per-request span capture + per-stage histograms + shed events
    /// (`GET /v1/trace`, the `trace` section of `/v1/metrics`, and the
    /// `pqs_trace_*` families of `GET /metrics`)
    pub(crate) tracer: Tracer,
    /// readiness kill-switch: flipped (before any connection closes) by
    /// [`HttpServer::set_draining`] / shutdown so `GET /readyz` reports
    /// not-ready while in-flight requests still complete
    pub(crate) draining: AtomicBool,
}

enum Backend {
    /// accept thread owning the connection pool (handed back on exit so
    /// shutdown can drain it after joining the loop)
    Blocking { accept: Option<JoinHandle<WorkerPool<TcpStream>>> },
    #[cfg(target_os = "linux")]
    Event { handle: Option<JoinHandle<()>>, waker: Arc<super::event_loop::Waker> },
}

/// The HTTP/1.1 serving front-end. Owns the [`Router`] it forwards
/// classification requests into; [`HttpServer::shutdown`] drains the
/// active backend, then every model server, and returns the final
/// [`FrontendReport`].
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    backend: Backend,
    ctx: Option<Arc<Ctx>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and serve
    /// requests into `router`.
    pub fn start(router: Router, addr: &str, cfg: HttpConfig) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let ctx = Arc::new(Ctx {
            router,
            cfg,
            next_id: AtomicU64::new(1),
            stop: Arc::clone(&stop),
            http: HttpCounters::default(),
            tracer: Tracer::new(cfg.trace),
            draining: AtomicBool::new(false),
        });

        #[cfg(target_os = "linux")]
        if cfg.event_loop {
            let (handle, waker) = super::event_loop::spawn(Arc::clone(&ctx), listener)?;
            return Ok(HttpServer {
                addr: local,
                stop,
                backend: Backend::Event { handle: Some(handle), waker },
                ctx: Some(ctx),
            });
        }

        let hctx = Arc::clone(&ctx);
        let conn_pool = WorkerPool::new(
            cfg.conn_threads.max(1),
            cfg.conn_backlog.max(1),
            move |stream: TcpStream| handle_connection(&hctx, stream),
        );

        // the accept thread owns the pool and hands it back on exit so
        // shutdown can drain it after joining the loop
        let astop = Arc::clone(&stop);
        let actx = Arc::clone(&ctx);
        let accept = std::thread::spawn(move || {
            let mut accept_err_reported = false;
            loop {
                if astop.load(Ordering::Acquire) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        // injected connection reset: drop before reading a
                        // byte, exactly like a peer RST between accept and
                        // first read (counted by the fault plan, never here)
                        if let Some(f) = actx.router.faults() {
                            if f.reset_accept() {
                                drop(stream);
                                continue;
                            }
                        }
                        // counted BEFORE dispatch: a handler can finish a
                        // whole request round-trip before this thread runs
                        // again, and that response must already see itself
                        // in `accepted` (shedding takes the count back)
                        actx.http.accepted.fetch_add(1, Ordering::Relaxed);
                        if let Err(shed) = conn_pool.try_dispatch(stream) {
                            actx.http.accepted.fetch_sub(1, Ordering::Relaxed);
                            actx.http.count_shed(SHED_QUEUE_FULL);
                            actx.tracer.record_shed(SHED_QUEUE_FULL);
                            shed_connection(shed);
                        }
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(e) => {
                        // real accept failure (e.g. fd exhaustion): surface
                        // it once instead of spinning silently, and back
                        // off harder than the poll tick
                        if !accept_err_reported {
                            accept_err_reported = true;
                            eprintln!("http accept error (backing off): {e}");
                        }
                        std::thread::sleep(Duration::from_millis(50));
                    }
                }
            }
            conn_pool
        });

        Ok(HttpServer {
            addr: local,
            stop,
            backend: Backend::Blocking { accept: Some(accept) },
            ctx: Some(ctx),
        })
    }

    /// The bound address (useful with an ephemeral `:0` bind).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Snapshot of the router's metrics (per-model sections included).
    pub fn metrics(&self) -> RouterMetrics {
        match &self.ctx {
            Some(ctx) => ctx.router.metrics(),
            None => RouterMetrics::default(),
        }
    }

    /// Snapshot of the front-end's own connection counters.
    pub fn http_metrics(&self) -> HttpMetrics {
        match &self.ctx {
            Some(ctx) => ctx.http.snapshot(),
            None => HttpMetrics::default(),
        }
    }

    /// The router's armed fault-injection plan, if any (`None` in
    /// production). Lets a soak driver disarm faults or read injected
    /// counts without keeping its own handle.
    pub fn faults(&self) -> Option<Arc<crate::faults::FaultPlan>> {
        self.ctx.as_ref().and_then(|c| c.router.faults().cloned())
    }

    /// Flip `GET /readyz` to not-ready WITHOUT closing anything: load
    /// balancers see 503 and stop sending new traffic while in-flight
    /// requests (and open keep-alive connections) keep working.
    /// [`HttpServer::shutdown`] calls this before touching a single
    /// connection; call it earlier yourself for a longer drain window.
    pub fn set_draining(&self) {
        if let Some(ctx) = &self.ctx {
            ctx.draining.store(true, Ordering::Release);
        }
    }

    /// Stop accepting connections, drain the active backend, shut every
    /// model server down (draining their queues), and return the final
    /// report.
    pub fn shutdown(mut self) -> FrontendReport {
        self.stop_and_drain();
        match self.ctx.take().map(Arc::try_unwrap) {
            Some(Ok(ctx)) => {
                let http = ctx.http.snapshot();
                FrontendReport { router: ctx.router.shutdown(), http }
            }
            // a handler leaked its context somehow: best-effort snapshot
            Some(Err(ctx)) => {
                FrontendReport { router: ctx.router.metrics(), http: ctx.http.snapshot() }
            }
            None => FrontendReport::default(),
        }
    }

    fn stop_and_drain(&mut self) {
        // readiness flips BEFORE any connection closes: a probe racing
        // the shutdown sees not-ready first, closed sockets second
        self.set_draining();
        self.stop.store(true, Ordering::Release);
        match &mut self.backend {
            Backend::Blocking { accept } => {
                if let Some(h) = accept.take() {
                    if let Ok(conn_pool) = h.join() {
                        conn_pool.shutdown();
                    }
                }
            }
            #[cfg(target_os = "linux")]
            Backend::Event { handle, waker } => {
                waker.wake();
                if let Some(h) = handle.take() {
                    let _ = h.join();
                }
            }
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop_and_drain();
    }
}

/// Best-effort raise of the process file-descriptor limit
/// (`RLIMIT_NOFILE`) to at least `want`; returns the resulting soft
/// limit. The event loop happily holds tens of thousands of sockets, but
/// the default soft limit (often 1024) caps it first — the connection
/// bench and the soak tests call this before opening large fleets.
/// No-op off Linux (returns `u64::MAX`).
pub fn raise_nofile_limit(want: u64) -> u64 {
    #[cfg(target_os = "linux")]
    {
        #[repr(C)]
        struct Rlimit {
            cur: u64,
            max: u64,
        }
        extern "C" {
            fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
            fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
        }
        const RLIMIT_NOFILE: i32 = 7;
        let mut rl = Rlimit { cur: 0, max: 0 };
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut rl) } != 0 {
            return 0;
        }
        if rl.cur < want {
            let bumped = Rlimit { cur: want.min(rl.max), max: rl.max };
            if unsafe { setrlimit(RLIMIT_NOFILE, &bumped) } == 0 {
                rl.cur = bumped.cur;
            }
        }
        rl.cur
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = want;
        u64::MAX
    }
}

// ---- blocking connection handling -----------------------------------------

/// Best-effort 503 for a connection the saturated backend cannot take.
/// Clears any inherited O_NONBLOCK and bounds the write so a dead peer
/// cannot stall the accept path.
pub(crate) fn shed_connection(mut stream: TcpStream) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_write_timeout(Some(Duration::from_millis(50)));
    let reply = Reply::retryable(503, "connection backlog full", false, 1);
    let _ = stream.write_all(&encode_reply(&reply, usize::MAX));
    let _ = stream.shutdown(std::net::Shutdown::Write);
}

fn handle_connection(ctx: &Ctx, mut stream: TcpStream) {
    // accepted sockets can inherit the listener's nonblocking flag on some
    // platforms; handlers use plain blocking reads with a short timeout
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(READ_TICK)).is_err() {
        return;
    }
    let threshold = ctx.cfg.stream_threshold;
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 8192];
    // idle is measured against a real clock, not accumulated read-timeout
    // ticks: a blocked read may return early (signal, spurious wakeup,
    // platform timeout slop), so counting `idle += READ_TICK` per
    // WouldBlock overcounts and can fire a premature close/408
    let mut last_activity = Instant::now();
    // first byte of the currently-buffered partial request: a request must
    // complete within keep_alive_timeout of it, so a slow-drip client
    // (one byte per tick) cannot pin a pool worker indefinitely
    let mut partial_since: Option<Instant> = None;
    loop {
        // answer every complete pipelined request already buffered
        loop {
            let step = match parser::parse_request(&buf, &ctx.cfg.limits) {
                Ok(Some((req, consumed))) => {
                    let reply = route(ctx, &req);
                    Some((encode_reply(&reply, threshold), reply.keep, consumed))
                }
                Ok(None) => None,
                Err(e) => {
                    let reply = Reply::error(e.status(), e.message(), false);
                    let _ = stream.write_all(&encode_reply(&reply, threshold));
                    return;
                }
            };
            match step {
                Some((resp, keep, consumed)) => {
                    if stream.write_all(&resp).is_err() {
                        return;
                    }
                    buf.drain(..consumed);
                    last_activity = Instant::now();
                    partial_since = None;
                    if !keep {
                        return;
                    }
                }
                None => break,
            }
        }
        if buf.is_empty() {
            partial_since = None;
        } else if let Some(t0) = partial_since {
            if t0.elapsed() >= ctx.cfg.keep_alive_timeout {
                ctx.http.read_timeouts.fetch_add(1, Ordering::Relaxed);
                let reply = Reply::error(408, "request incomplete", false);
                let _ = stream.write_all(&encode_reply(&reply, threshold));
                return;
            }
        } else {
            partial_since = Some(Instant::now());
        }
        if ctx.stop.load(Ordering::Acquire) {
            return;
        }
        match stream.read(&mut chunk) {
            Ok(0) => return, // peer closed
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                last_activity = Instant::now();
            }
            Err(ref e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if last_activity.elapsed() >= ctx.cfg.keep_alive_timeout {
                    if !buf.is_empty() {
                        // a partial request stalled mid-flight
                        ctx.http.read_timeouts.fetch_add(1, Ordering::Relaxed);
                        let reply = Reply::error(408, "request incomplete", false);
                        let _ = stream.write_all(&encode_reply(&reply, threshold));
                    }
                    return;
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

// ---- request dispatch -----------------------------------------------------

/// One response, ready for [`encode_reply`]. Carries framing context
/// (HEAD, HTTP version) alongside the payload so both backends encode
/// identically.
pub(crate) struct Reply {
    pub(crate) status: u16,
    /// `Allow` header for 405s
    pub(crate) allow: Option<&'static str>,
    /// `Retry-After` delta-seconds for 503/504s that are worth retrying
    /// (full queue, Open breaker, missed deadline); `None` on errors
    /// retrying cannot fix (quarantine, bad request)
    pub(crate) retry_after: Option<u64>,
    /// payload text (the would-be payload for HEAD)
    pub(crate) body: String,
    /// `Content-Type` of the body; almost always JSON — `GET /metrics`
    /// answers in the Prometheus text exposition format instead
    pub(crate) content_type: &'static str,
    /// trace ID echoed back as `X-Request-Id` (classify responses when
    /// tracing is enabled; `None` elsewhere)
    pub(crate) request_id: Option<String>,
    /// keep the connection open after this response
    pub(crate) keep: bool,
    /// HEAD semantics: emit GET's status and headers (`Content-Length`
    /// of the would-be body), no body
    pub(crate) head_only: bool,
    /// request was HTTP/1.1 (chunked streaming allowed); defaults true,
    /// corrected from the request's version wherever one was parsed
    pub(crate) http11: bool,
}

impl Reply {
    pub(crate) fn new(status: u16, body: String, keep: bool) -> Reply {
        Reply {
            status,
            allow: None,
            retry_after: None,
            body,
            content_type: "application/json",
            request_id: None,
            keep,
            head_only: false,
            http11: true,
        }
    }

    pub(crate) fn error(status: u16, message: &str, keep: bool) -> Reply {
        Reply::new(status, json::obj(vec![("error", json::s(message))]).to_string(), keep)
    }

    /// An error the client should retry `after_s` seconds later
    /// (`Retry-After` is emitted on the wire).
    pub(crate) fn retryable(status: u16, message: &str, keep: bool, after_s: u64) -> Reply {
        let mut r = Reply::error(status, message, keep);
        r.retry_after = Some(after_s.max(1));
        r
    }
}

fn method_not_allowed(allow: &'static str, keep: bool) -> Reply {
    let mut r = Reply::error(405, "method not allowed", keep);
    r.allow = Some(allow);
    r
}

/// Answer everything that never touches an engine: the GET/HEAD
/// endpoints, 404s and 405s. Cheap, lock-light CPU work — the event loop
/// runs this inline. Returns `None` for `POST /v1/classify`, which needs
/// the blocking [`prepare_classify`] + [`run_classify`] pair.
///
/// Per RFC 9110 §9.3.2 `HEAD` is supported wherever `GET` is: it returns
/// GET's status and headers (`Content-Length` of the would-be body) with
/// no body — load-balancer health probes on `/healthz` see 200, not 405.
pub(crate) fn route_fast(ctx: &Ctx, req: &Request<'_>) -> Option<Reply> {
    let keep = req.keep_alive() && !ctx.stop.load(Ordering::Acquire);
    let mut reply = match (req.method, req.path()) {
        ("GET" | "HEAD", "/healthz") => {
            Reply::new(200, json::obj(vec![("status", json::s("ok"))]).to_string(), keep)
        }
        ("GET" | "HEAD", "/readyz") => readyz_reply(ctx, keep),
        ("GET" | "HEAD", "/v1/metrics") => Reply::new(
            200,
            metrics_json(&ctx.router.metrics(), &ctx.http.snapshot(), &ctx.tracer),
            keep,
        ),
        ("GET" | "HEAD", "/v1/models") => {
            Reply::new(200, models_json(ctx.router.default_model(), &ctx.router.models()), keep)
        }
        ("GET" | "HEAD", "/v1/trace") => {
            let n = trace_query_n(req.target);
            Reply::new(200, ctx.tracer.trace_json(n).to_string(), keep)
        }
        ("GET" | "HEAD", "/metrics") => {
            let mut r = Reply::new(200, prometheus_text(ctx), keep);
            r.content_type = "text/plain; version=0.0.4";
            r
        }
        ("POST", "/v1/classify") => return None,
        (_, "/healthz") | (_, "/readyz") | (_, "/v1/metrics") | (_, "/v1/models")
        | (_, "/v1/trace") | (_, "/metrics") => method_not_allowed("GET, HEAD", keep),
        (_, "/v1/classify") => method_not_allowed("POST", keep),
        _ => Reply::error(404, "no such endpoint", keep),
    };
    // a HEAD response never carries a body, whatever the status
    reply.head_only = req.method == "HEAD";
    reply.http11 = req.version == Version::Http11;
    Some(reply)
}

/// The `GET /readyz` answer: readiness, as distinct from `/healthz`
/// liveness. Live = the process answers at all (always 200 while it
/// runs). Ready = it should receive NEW traffic: not draining, default
/// model neither quarantined nor behind an Open breaker
/// ([`Router::ready`]), and the default queue below a 90% high
/// watermark — readiness sheds load *before* the queue starts 503ing.
/// Not-ready is `503` + `Retry-After: 1`; the body always carries the
/// individual gates so an operator sees which one failed.
fn readyz_reply(ctx: &Ctx, keep: bool) -> Reply {
    let draining = ctx.draining.load(Ordering::Acquire) || ctx.stop.load(Ordering::Acquire);
    let model_ok = ctx.router.ready();
    let (qlen, qcap) = ctx.router.default_queue_depth().unwrap_or((0, 0));
    let queue_ok = qcap == 0 || qlen * 10 < qcap * 9;
    let ready = !draining && model_ok && queue_ok;
    let body = json::obj(vec![
        ("ready", Json::Bool(ready)),
        ("draining", Json::Bool(draining)),
        ("default_model_ok", Json::Bool(model_ok)),
        ("queue_len", json::num(qlen as f64)),
        ("queue_cap", json::num(qcap as f64)),
    ])
    .to_string();
    if ready {
        Reply::new(200, body, keep)
    } else {
        let mut r = Reply::new(503, body, keep);
        r.retry_after = Some(1);
        r
    }
}

/// The `n` query parameter of `GET /v1/trace?n=K` (the whole ring when
/// absent or malformed — `path()` strips the query, so this reads the
/// raw target).
fn trace_query_n(target: &str) -> usize {
    target
        .split_once('?')
        .map(|(_, q)| q)
        .into_iter()
        .flat_map(|q| q.split('&'))
        .find_map(|kv| kv.strip_prefix("n="))
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(usize::MAX)
}

/// Microseconds elapsed since `t0`.
fn us_since(t0: Instant) -> f64 {
    t0.elapsed().as_secs_f64() * 1e6
}

/// Full blocking dispatch of one parsed request (the fallback backend's
/// path; the event loop splits the same stages across loop and workers).
/// `arrived` anchors the request's trace span: as close to the bytes'
/// arrival as the backend can observe (here: parse completion, since the
/// blocking read loop interleaves reads of many pipelined requests).
fn route(ctx: &Ctx, req: &Request<'_>) -> Reply {
    let arrived = Instant::now();
    if let Some(reply) = route_fast(ctx, req) {
        return reply;
    }
    let keep = req.keep_alive() && !ctx.stop.load(Ordering::Acquire);
    let http11 = req.version == Version::Http11;
    match prepare_classify(ctx, req, keep, arrived) {
        Ok(request) => run_classify(ctx, request, keep, http11),
        Err(reply) => reply,
    }
}

/// Decode and validate a `POST /v1/classify` payload into an owned
/// [`ClassifyRequest`]. Pure CPU work (JSON parse + shape checks), cheap
/// enough for the event loop to run inline; the owned result lets the
/// blocking router calls run on a worker thread afterwards.
///
/// `arrived` is when the backend first saw this request (span anchor).
/// When tracing is enabled the trace context rides the returned request:
/// the ID comes from a valid `X-Request-Id` header (1–128 chars of
/// `[A-Za-z0-9._-]`; an invalid one is a 400, never silently replaced)
/// or is generated, and is echoed on every classify response — including
/// the 400s built here, which also record an error span.
pub(crate) fn prepare_classify(
    ctx: &Ctx,
    req: &Request<'_>,
    keep: bool,
    arrived: Instant,
) -> Result<ClassifyRequest, Reply> {
    let http11 = req.version == Version::Http11;
    let trace = match (ctx.tracer.enabled(), req.header("x-request-id")) {
        (false, _) => None,
        (true, Some(id)) => {
            if !trace::valid_request_id(id) {
                let mut r = Reply::error(
                    400,
                    &format!(
                        "invalid X-Request-Id: want 1..={MAX_REQUEST_ID_LEN} characters of \
                         [A-Za-z0-9._-]"
                    ),
                    keep,
                );
                r.http11 = http11;
                return Err(r);
            }
            Some(RequestTrace {
                id: id.to_string(),
                sampled: ctx.tracer.should_sample(),
                start: arrived,
                parse_us: 0.0,
            })
        }
        (true, None) => Some(RequestTrace {
            id: ctx.tracer.next_id(),
            sampled: ctx.tracer.should_sample(),
            start: arrived,
            parse_us: 0.0,
        }),
    };
    let fail = |msg: &str| {
        let mut r = Reply::error(400, msg, keep);
        r.http11 = http11;
        if let Some(t) = &trace {
            // even a malformed classify echoes its ID and (being an
            // error) always reaches the trace ring: one clock read
            // serves as both the parse stage and the span total
            let us = us_since(t.start);
            ctx.tracer.record(TraceSpan {
                id: t.id.clone(),
                model: None,
                status: 400,
                sampled: t.sampled,
                overflow: false,
                shed_reason: None,
                total_us: us,
                stages: SpanStages { parse_us: us, ..SpanStages::default() },
                layers: Vec::new(),
            });
            r.request_id = Some(t.id.clone());
        }
        r
    };
    let payload = match Json::parse_bytes(&req.body) {
        Ok(j) => j,
        Err(e) => return Err(fail(&format!("invalid json body: {e}"))),
    };
    // decode the pixels straight into the f32 batch buffer (one
    // allocation, not an intermediate Vec<f64>)
    let image: Vec<f32> = match payload.get("image").and_then(Json::as_arr) {
        Some(arr) => {
            let mut img = Vec::with_capacity(arr.len());
            for v in arr {
                match v.as_f64() {
                    Some(x) => img.push(x as f32),
                    None => return Err(fail("\"image\" must contain only numbers")),
                }
            }
            img
        }
        None => return Err(fail("body must carry a numeric \"image\" array")),
    };
    // id is echoed back verbatim, so a present-but-invalid id is a 400,
    // never silently replaced; an absent id is auto-assigned
    let id = match payload.get("id") {
        None => ctx.next_id.fetch_add(1, Ordering::Relaxed),
        Some(v) => match v.as_i64().and_then(|i| u64::try_from(i).ok()) {
            Some(i) => i,
            None => return Err(fail("\"id\" must be a non-negative integer")),
        },
    };
    // route target: a present-but-non-string model is a 400 (a typo must
    // not silently fall through to the default model); absent = default
    let model: Option<String> = match payload.get("model") {
        None => None,
        Some(v) => match v.as_str() {
            Some(s) => Some(s.to_string()),
            None => return Err(fail("\"model\" must be a string")),
        },
    };
    // clamp to [0, 1 day] and reject non-finite values so a hostile
    // payload can never panic Duration::from_secs_f64 (which would kill a
    // pool worker)
    let deadline = payload
        .get("deadline_ms")
        .and_then(Json::as_f64)
        .filter(|ms| ms.is_finite())
        .map(|ms| Duration::from_secs_f64(ms.clamp(0.0, 86_400_000.0) / 1e3));
    // per-request accumulator operating point ("operating_point" is an
    // accepted alias). Only the field's shape is checked here; the width
    // itself is validated against the routed model's embedded plan by its
    // server, which answers an under-bound or plan-free override with
    // BadRequest → 400
    let acc_field = match (payload.get("acc_bits"), payload.get("operating_point")) {
        (Some(_), Some(_)) => {
            return Err(fail("use \"acc_bits\" or \"operating_point\", not both"))
        }
        (v, None) | (None, v) => v,
    };
    let acc_bits: Option<u32> = match acc_field {
        None => None,
        Some(v) => match v.as_i64().and_then(|i| u32::try_from(i).ok()).filter(|&b| b > 0) {
            Some(b) => Some(b),
            None => return Err(fail("\"acc_bits\" must be a positive integer")),
        },
    };
    let mut trace = trace;
    if let Some(t) = &mut trace {
        t.parse_us = us_since(t.start);
    }
    Ok(ClassifyRequest { id, model, image, deadline, acc_bits, trace })
}

/// Submit one validated request into the router and wait (blocking) for
/// its response. Runs on a connection-pool thread under the blocking
/// backend and on a classify worker under the event loop — never on the
/// event loop thread itself (`Router::try_submit` may lazily load a
/// model and `wait_timeout` parks for up to `response_timeout`).
pub(crate) fn run_classify(
    ctx: &Ctx,
    request: ClassifyRequest,
    keep: bool,
    http11: bool,
) -> Reply {
    let mut reply = run_classify_inner(ctx, request, keep);
    reply.http11 = http11;
    reply
}

/// What [`classify_route`] observed along the way, for span assembly:
/// stage boundary instants, the engine's per-batch stamp, the shed
/// reason (when the answer was a queue-full / draining 503).
struct ClassifyObs {
    /// when `Router::try_submit` returned (routing — lazy load, breaker
    /// gate, queue admission — done, for better or worse)
    routed_at: Instant,
    /// when the response (or timeout/route error) was in hand
    responded_at: Instant,
    /// set when an engine actually answered
    engine: Option<EngineObs>,
    shed_reason: Option<&'static str>,
}

/// The engine-side facts of one answered request.
struct EngineObs {
    batch_us: f64,
    compute_us: f64,
    layer_us: Arc<Vec<(String, f64)>>,
    overflow: bool,
}

fn run_classify_inner(ctx: &Ctx, mut request: ClassifyRequest, keep: bool) -> Reply {
    let trace = request.trace.take();
    // resolve the span's model label up front: the router consumes the
    // request, and `None` routes to the default
    let model = trace.as_ref().map(|_| match &request.model {
        Some(m) => m.clone(),
        None => ctx.router.default_model().to_string(),
    });
    let now = Instant::now();
    let mut obs =
        ClassifyObs { routed_at: now, responded_at: now, engine: None, shed_reason: None };
    let mut reply = classify_route(ctx, request, keep, &mut obs);
    if let Some(t) = trace {
        // stage decomposition, clamped so stages can never sum past the
        // span total: parse+route end at `routed_at`; the wait between
        // `routed_at` and `responded_at` splits into forward (engine
        // invocation), batch (assembly) and queue (the remainder) using
        // the engine's own stamps bounded by the observed wait
        let to_routed = obs.routed_at.duration_since(t.start).as_secs_f64() * 1e6;
        let route_us = (to_routed - t.parse_us).max(0.0);
        let wait_us = obs.responded_at.duration_since(obs.routed_at).as_secs_f64() * 1e6;
        let (queue_us, batch_us, forward_us, layers, overflow) = match &obs.engine {
            Some(e) => {
                let forward = e.compute_us.min(wait_us);
                let batch = e.batch_us.min(wait_us - forward);
                let queue = wait_us - forward - batch;
                (queue, batch, forward, (*e.layer_us).clone(), e.overflow)
            }
            None => (wait_us, 0.0, 0.0, Vec::new(), false),
        };
        let respond_us = us_since(obs.responded_at);
        let stages = SpanStages {
            parse_us: t.parse_us,
            route_us,
            queue_us,
            batch_us,
            forward_us,
            respond_us,
        };
        // measured LAST, after every stage: an honest upper bound
        let total_us = us_since(t.start);
        ctx.tracer.record(TraceSpan {
            id: t.id.clone(),
            model,
            status: reply.status,
            sampled: t.sampled,
            overflow,
            shed_reason: obs.shed_reason,
            total_us,
            stages,
            layers,
        });
        reply.request_id = Some(t.id);
    }
    reply
}

/// Route + wait for one classify request, recording stage boundaries and
/// engine facts into `obs` (the caller assembles the trace span).
fn classify_route(
    ctx: &Ctx,
    request: ClassifyRequest,
    keep: bool,
    obs: &mut ClassifyObs,
) -> Reply {
    let pending = match ctx.router.try_submit(request) {
        Ok(p) => p,
        Err(e) => {
            obs.routed_at = Instant::now();
            obs.responded_at = obs.routed_at;
            return match e {
                RouteError::UnknownModel(msg) => Reply::error(404, &msg, keep),
                RouteError::LoadFailed(msg) => Reply::error(500, &msg, keep),
                e @ RouteError::BreakerOpen { .. } => {
                    // Retry-After = the breaker's remaining backoff,
                    // rounded up: a client honoring it lands just after
                    // the Half-Open probe
                    let after = match &e {
                        RouteError::BreakerOpen { retry_after, .. } => {
                            retry_after.as_secs_f64().ceil() as u64
                        }
                        _ => 1,
                    };
                    Reply::retryable(503, &e.to_string(), keep, after)
                }
                // no Retry-After: a quarantine outlives any client
                // backoff (it ends only at an explicit operator reload)
                e @ RouteError::Quarantined { .. } => Reply::error(503, &e.to_string(), keep),
                RouteError::Rejected(e) => {
                    let reason = match &e {
                        SubmitError::Full(_) => SHED_QUEUE_FULL,
                        SubmitError::Closed(_) => SHED_DRAINING,
                    };
                    obs.shed_reason = Some(reason);
                    ctx.http.count_shed(reason);
                    // a closing server also closes the connection; a full
                    // queue is transient, so the connection stays usable
                    // for a retry
                    let keep = keep && !matches!(e, SubmitError::Closed(_));
                    Reply::retryable(503, &RouteError::Rejected(e).to_string(), keep, 1)
                }
            };
        }
    };
    obs.routed_at = Instant::now();
    let resp = match pending.wait_timeout(ctx.cfg.response_timeout) {
        Some(r) => r,
        None => {
            obs.responded_at = Instant::now();
            return Reply::retryable(504, "timed out waiting for the engine", keep, 1);
        }
    };
    obs.responded_at = Instant::now();
    obs.engine = Some(EngineObs {
        batch_us: resp.batch_us,
        compute_us: resp.compute_us,
        layer_us: Arc::clone(&resp.layer_us),
        overflow: resp.overflow,
    });
    match resp.result {
        Ok(class) => {
            let body = json::obj(vec![
                ("id", json::num(resp.id as f64)),
                ("class", json::num(class as f64)),
                ("queue_us", json::num(resp.queue_us)),
                ("compute_us", json::num(resp.compute_us)),
                ("latency_us", json::num(resp.latency_us)),
                ("batch_size", json::num(resp.batch_size as f64)),
            ])
            .to_string();
            Reply::new(200, body, keep)
        }
        Err(ServeError::Expired { waited_us }) => {
            let body = json::obj(vec![
                ("error", json::s("deadline exceeded before the engine picked it up")),
                ("id", json::num(resp.id as f64)),
                ("waited_us", json::num(waited_us as f64)),
            ])
            .to_string();
            // retrying after the linger window is worthwhile: the queue
            // that starved this request has (at least) batch-drained since
            let mut r = Reply::new(504, body, keep);
            r.retry_after = Some(1);
            r
        }
        Err(ServeError::BadRequest(m)) => Reply::error(400, &m, keep),
        Err(ServeError::Internal(m)) => Reply::error(500, &m, keep),
    }
}

// ---- response framing -----------------------------------------------------

fn status_reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Content Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Serialize one response. Bodies past `stream_threshold` stream as
/// `Transfer-Encoding: chunked` when the request was HTTP/1.1 (a 1.0
/// client cannot parse chunked framing, so it always gets
/// `Content-Length`); the decoded payload is byte-identical either way.
/// HEAD responses carry GET's headers — `Content-Length` of the would-be
/// body — and no body at all.
pub(crate) fn encode_reply(r: &Reply, stream_threshold: usize) -> Vec<u8> {
    let body = r.body.as_bytes();
    let chunked = r.http11 && !r.head_only && body.len() > stream_threshold;
    let mut out = Vec::with_capacity(body.len() + 160);
    out.extend_from_slice(b"HTTP/1.1 ");
    out.extend_from_slice(r.status.to_string().as_bytes());
    out.push(b' ');
    out.extend_from_slice(status_reason(r.status).as_bytes());
    out.extend_from_slice(b"\r\nContent-Type: ");
    out.extend_from_slice(r.content_type.as_bytes());
    out.extend_from_slice(b"\r\n");
    if let Some(id) = &r.request_id {
        out.extend_from_slice(b"X-Request-Id: ");
        out.extend_from_slice(id.as_bytes());
        out.extend_from_slice(b"\r\n");
    }
    if chunked {
        out.extend_from_slice(b"Transfer-Encoding: chunked\r\n");
    } else {
        out.extend_from_slice(b"Content-Length: ");
        out.extend_from_slice(body.len().to_string().as_bytes());
        out.extend_from_slice(b"\r\n");
    }
    out.extend_from_slice(b"Connection: ");
    out.extend_from_slice(if r.keep { b"keep-alive" as &[u8] } else { b"close" });
    out.extend_from_slice(b"\r\n");
    if let Some(allow) = r.allow {
        out.extend_from_slice(b"Allow: ");
        out.extend_from_slice(allow.as_bytes());
        out.extend_from_slice(b"\r\n");
    }
    if let Some(after) = r.retry_after {
        out.extend_from_slice(b"Retry-After: ");
        out.extend_from_slice(after.to_string().as_bytes());
        out.extend_from_slice(b"\r\n");
    }
    out.extend_from_slice(b"\r\n");
    if r.head_only {
        return out;
    }
    if chunked {
        parser::encode_chunked(body, RESPONSE_CHUNK, &mut out);
    } else {
        out.extend_from_slice(body);
    }
    out
}

// ---- JSON serialization of the metrics surfaces ---------------------------

fn summary_json(r: &LatencySummary) -> Json {
    json::obj(vec![
        ("count", json::num(r.count as f64)),
        ("mean_us", json::num(r.mean_us)),
        ("p50_us", json::num(r.p50_us)),
        ("p95_us", json::num(r.p95_us)),
        ("p99_us", json::num(r.p99_us)),
        ("p999_us", json::num(r.p999_us)),
        ("max_us", json::num(r.max_us)),
    ])
}

fn plan_json(plan: &Option<PlanSummary>) -> Json {
    match plan {
        Some(p) => json::obj(vec![
            ("planner", json::s(p.planner.name())),
            ("layers", json::num(p.layers as f64)),
            ("min_bits", json::num(p.min_bits as f64)),
            ("max_bits", json::num(p.max_bits as f64)),
            ("mean_bits", json::num(p.mean_bits)),
        ]),
        None => Json::Null,
    }
}

fn serve_metrics_json(m: &ServeSummary) -> Json {
    json::obj(vec![
        ("requests", json::num(m.requests as f64)),
        ("errors", json::num(m.errors as f64)),
        ("expired", json::num(m.expired as f64)),
        ("panics", json::num(m.panics as f64)),
        ("batches", json::num(m.batches as f64)),
        ("mean_batch", json::num(m.mean_batch)),
        ("throughput_rps", json::num(m.throughput_rps)),
        ("wall_s", json::num(m.wall_s)),
        ("latency", summary_json(&m.latency)),
        ("queue", summary_json(&m.queue)),
        ("compute", summary_json(&m.compute)),
    ])
}

/// One model's self-healing state as it appears per row in
/// `GET /v1/models` and per model section in `GET /v1/metrics`.
fn health_json(h: &ModelHealth) -> Json {
    json::obj(vec![
        ("breaker", json::s(h.breaker.as_str())),
        ("retry_after_s", json::num(h.retry_after_s)),
        ("consecutive_failures", json::num(h.consecutive_failures as f64)),
        ("load_retries", json::num(h.load_retries as f64)),
        ("breaker_opens", json::num(h.breaker_opens as f64)),
        ("fast_fails", json::num(h.fast_fails as f64)),
        ("quarantined", h.quarantined.as_deref().map_or(Json::Null, json::s)),
    ])
}

fn shape_json(shape: &Option<Vec<usize>>) -> Json {
    match shape {
        Some(s) => Json::Arr(s.iter().map(|&d| json::num(d as f64)).collect()),
        None => Json::Null,
    }
}

/// The `GET /v1/metrics` document: aggregate counters at the top level
/// (old single-model clients keep working), then `router` counters,
/// per-model sections under `models`, the front-end's `http` counters
/// (sheds broken out per reason), per-stage trace histograms under
/// `trace`, and the shared compute pool (`null` when engines run
/// single-threaded).
fn metrics_json(rm: &RouterMetrics, hm: &HttpMetrics, tracer: &Tracer) -> String {
    let agg = rm.aggregate();
    let models = Json::Obj(
        rm.models
            .iter()
            .map(|m| {
                let mut obj = match serve_metrics_json(&m.metrics) {
                    Json::Obj(o) => o,
                    _ => unreachable!("serve_metrics_json returns an object"),
                };
                obj.insert("loaded".into(), Json::Bool(m.loaded));
                obj.insert("default".into(), Json::Bool(m.default));
                obj.insert("input_shape".into(), shape_json(&m.input_shape));
                obj.insert("plan".into(), plan_json(&m.plan));
                obj.insert("health".into(), health_json(&m.health));
                (m.name.clone(), Json::Obj(obj))
            })
            .collect(),
    );
    // pool utilization of the shared intra-forward compute pool; `null`
    // when every engine runs single-threaded
    let pool = match &rm.pool {
        Some(p) => json::obj(vec![
            ("threads", json::num(p.threads as f64)),
            ("busy", json::num(p.busy as f64)),
            ("jobs", json::num(p.jobs as f64)),
            ("inline_jobs", json::num(p.inline_jobs as f64)),
            ("chunks", json::num(p.chunks as f64)),
        ]),
        None => Json::Null,
    };
    json::obj(vec![
        ("requests", json::num(agg.requests as f64)),
        ("errors", json::num(agg.errors as f64)),
        ("expired", json::num(agg.expired as f64)),
        ("panics", json::num(agg.panics as f64)),
        ("batches", json::num(agg.batches as f64)),
        ("mean_batch", json::num(agg.mean_batch)),
        ("throughput_rps", json::num(agg.throughput_rps)),
        ("wall_s", json::num(agg.wall_s)),
        ("latency", summary_json(&agg.latency)),
        ("queue", summary_json(&agg.queue)),
        ("compute", summary_json(&agg.compute)),
        (
            "router",
            json::obj(vec![
                ("routed", json::num(rm.routed as f64)),
                ("unknown_model", json::num(rm.unknown_model as f64)),
                ("loads", json::num(rm.loads as f64)),
                ("evictions", json::num(rm.evictions as f64)),
                ("resident_bytes", json::num(rm.resident_bytes as f64)),
                ("budget", json::num(rm.budget as f64)),
                ("dedup_hits", json::num(rm.dedup_hits as f64)),
                ("load_retries", json::num(rm.load_retries as f64)),
                ("breaker_opens", json::num(rm.breaker_opens as f64)),
                ("breaker_fast_fails", json::num(rm.breaker_fast_fails as f64)),
                ("quarantined", json::num(rm.quarantined as f64)),
                ("load_latency", summary_json(&rm.load_latency)),
            ]),
        ),
        ("models", models),
        (
            "http",
            json::obj(vec![
                ("accepted", json::num(hm.accepted as f64)),
                ("shed", json::num(hm.shed as f64)),
                ("shed_queue_full", json::num(hm.shed_queue_full as f64)),
                ("shed_max_connections", json::num(hm.shed_max_connections as f64)),
                ("shed_draining", json::num(hm.shed_draining as f64)),
                ("read_timeouts", json::num(hm.read_timeouts as f64)),
            ]),
        ),
        ("trace", tracer.stages_json()),
        ("pool", pool),
    ])
    .to_string()
}

/// The `GET /v1/models` document: the default route and one row per
/// registered model (load state, input shape, embedded accumulator-plan
/// summary, per-model metrics, and — while the engine is live — the
/// per-layer accumulator-headroom snapshot).
fn models_json(default: &str, models: &[ModelStatus]) -> String {
    let rows: Vec<Json> = models
        .iter()
        .map(|m| {
            json::obj(vec![
                ("name", json::s(&m.name)),
                ("default", Json::Bool(m.default)),
                ("loaded", Json::Bool(m.loaded)),
                ("input_shape", shape_json(&m.input_shape)),
                ("plan", plan_json(&m.plan)),
                (
                    "resident_bytes",
                    m.resident_bytes.map_or(Json::Null, |b| json::num(b as f64)),
                ),
                ("health", health_json(&m.health)),
                ("metrics", serve_metrics_json(&m.metrics)),
                (
                    "headroom",
                    m.headroom.as_ref().map_or(Json::Null, |h| trace::headroom_json(h)),
                ),
            ])
        })
        .collect();
    json::obj(vec![("default", json::s(default)), ("models", Json::Arr(rows))]).to_string()
}

/// The `GET /metrics` document: Prometheus text exposition format
/// 0.0.4. Fleet counters and gauges mirror `/v1/metrics`; per-stage
/// span timings export as one histogram family labeled by stage; the
/// per-model per-layer accumulator headroom exports as gauges so a
/// scrape can alert on `pqs_headroom_min_bits` approaching zero long
/// before a clip or wrap shows up in accuracy.
fn prometheus_text(ctx: &Ctx) -> String {
    let rm = ctx.router.metrics();
    let agg = rm.aggregate();
    let hm = ctx.http.snapshot();
    let (recorded, dropped) = ctx.tracer.counts();
    let mut p = PromText::new();

    let counters = [
        ("pqs_requests_total", "Requests answered by an engine.", agg.requests as f64),
        ("pqs_errors_total", "Requests answered with an engine error.", agg.errors as f64),
        ("pqs_expired_total", "Requests whose deadline expired in queue.", agg.expired as f64),
        ("pqs_panics_total", "Worker panics isolated by the serving loop.", agg.panics as f64),
        ("pqs_batches_total", "Engine forward batches executed.", agg.batches as f64),
        ("pqs_router_routed_total", "Requests routed to a model queue.", rm.routed as f64),
        (
            "pqs_router_unknown_model_total",
            "Requests naming an unregistered model.",
            rm.unknown_model as f64,
        ),
        ("pqs_router_loads_total", "Model engine loads.", rm.loads as f64),
        ("pqs_router_evictions_total", "Model engines evicted.", rm.evictions as f64),
        ("pqs_router_dedup_hits_total", "Duplicate loads coalesced.", rm.dedup_hits as f64),
        ("pqs_router_load_retries_total", "Model load retries.", rm.load_retries as f64),
        ("pqs_router_breaker_opens_total", "Circuit breaker opens.", rm.breaker_opens as f64),
        (
            "pqs_router_breaker_fast_fails_total",
            "Requests fast-failed by an open breaker.",
            rm.breaker_fast_fails as f64,
        ),
        ("pqs_http_accepted_total", "Connections accepted.", hm.accepted as f64),
        ("pqs_http_read_timeouts_total", "Connections timed out reading.", hm.read_timeouts as f64),
        ("pqs_trace_spans_recorded_total", "Trace spans recorded.", recorded as f64),
        (
            "pqs_trace_spans_dropped_total",
            "Trace spans evicted from the ring.",
            dropped as f64,
        ),
    ];
    for (name, help, v) in counters {
        p.metric(name, "counter", help, v);
    }

    let loaded = rm.models.iter().filter(|m| m.loaded).count();
    let gauges = [
        ("pqs_resident_bytes", "Bytes of model weights resident.", rm.resident_bytes as f64),
        ("pqs_memory_budget_bytes", "Fleet weight-memory budget.", rm.budget as f64),
        ("pqs_quarantined_models", "Models under quarantine.", rm.quarantined as f64),
        ("pqs_models_loaded", "Models with a live engine.", loaded as f64),
    ];
    for (name, help, v) in gauges {
        p.metric(name, "gauge", help, v);
    }

    p.family("pqs_http_shed_total", "counter", "Work shed with 503, by reason.");
    p.sample("pqs_http_shed_total", &[("reason", SHED_QUEUE_FULL)], hm.shed_queue_full as f64);
    p.sample(
        "pqs_http_shed_total",
        &[("reason", SHED_MAX_CONNECTIONS)],
        hm.shed_max_connections as f64,
    );
    p.sample("pqs_http_shed_total", &[("reason", SHED_DRAINING)], hm.shed_draining as f64);

    p.family("pqs_latency_us", "summary", "End-to-end classify latency in microseconds.");
    let lat = &agg.latency;
    for (q, v) in [("0.5", lat.p50_us), ("0.99", lat.p99_us), ("0.999", lat.p999_us)] {
        p.sample("pqs_latency_us", &[("quantile", q)], v);
    }
    p.sample("pqs_latency_us_sum", &[], lat.mean_us * lat.count as f64);
    p.sample("pqs_latency_us_count", &[], lat.count as f64);

    p.family("pqs_trace_stage_us", "histogram", "Per-stage span durations in microseconds.");
    for (stage, h) in ctx.tracer.stage_hists() {
        p.histogram_rows("pqs_trace_stage_us", &[("stage", stage)], &h);
    }

    p.family("pqs_headroom_planned_bits", "gauge", "Accumulator width the layer serves at.");
    p.family("pqs_headroom_max_required_bits", "gauge", "Widest observed per-dot requirement.");
    p.family("pqs_headroom_min_bits", "gauge", "Minimum observed headroom (planned - required).");
    p.family("pqs_headroom_dots_total", "counter", "Dots observed by the overflow monitor.");
    p.family("pqs_headroom_overflow_dots_total", "counter", "Dots that overflowed at serving.");
    p.family(
        "pqs_headroom_near_saturation_dots_total",
        "counter",
        "Dots within one bit of the planned width.",
    );
    for m in &rm.models {
        if let Some(rows) = &m.headroom {
            for l in rows {
                let lbl = [("model", m.name.as_str()), ("layer", l.layer.as_str())];
                let near = l.near_saturation_dots as f64;
                p.sample("pqs_headroom_planned_bits", &lbl, l.planned_bits as f64);
                p.sample("pqs_headroom_max_required_bits", &lbl, l.max_required_bits as f64);
                p.sample("pqs_headroom_min_bits", &lbl, l.min_headroom_bits as f64);
                p.sample("pqs_headroom_dots_total", &lbl, l.dots as f64);
                p.sample("pqs_headroom_overflow_dots_total", &lbl, l.overflow_dots as f64);
                p.sample("pqs_headroom_near_saturation_dots_total", &lbl, near);
            }
        }
    }
    p.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn head_of(bytes: &[u8]) -> String {
        let pos = bytes.windows(4).position(|w| w == b"\r\n\r\n").expect("head terminator");
        String::from_utf8_lossy(&bytes[..pos + 4]).into_owned()
    }

    fn body_of(bytes: &[u8]) -> &[u8] {
        let pos = bytes.windows(4).position(|w| w == b"\r\n\r\n").expect("head terminator");
        &bytes[pos + 4..]
    }

    #[test]
    fn small_bodies_use_content_length() {
        let r = Reply::new(200, "{\"ok\":1}".into(), true);
        let bytes = encode_reply(&r, 1024);
        let head = head_of(&bytes);
        assert!(head.starts_with("HTTP/1.1 200 OK\r\n"), "{head}");
        assert!(head.contains("Content-Length: 8\r\n"), "{head}");
        assert!(!head.contains("Transfer-Encoding"), "{head}");
        assert!(head.contains("Connection: keep-alive\r\n"), "{head}");
        assert_eq!(body_of(&bytes), b"{\"ok\":1}");
    }

    #[test]
    fn large_bodies_stream_chunked_and_decode_byte_identically() {
        let payload: String = "x".repeat(RESPONSE_CHUNK * 2 + 100);
        let r = Reply::new(200, payload.clone(), true);
        let bytes = encode_reply(&r, 64);
        let head = head_of(&bytes);
        assert!(head.contains("Transfer-Encoding: chunked\r\n"), "{head}");
        assert!(!head.contains("Content-Length"), "{head}");
        // decode the chunked framing back through the request parser
        let mut fake = b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec();
        fake.extend_from_slice(body_of(&bytes));
        let (req, consumed) =
            parser::parse_request(&fake, &Limits::default()).expect("valid").expect("complete");
        assert_eq!(consumed, fake.len());
        assert_eq!(&req.body[..], payload.as_bytes());
    }

    #[test]
    fn http10_never_gets_chunked_framing() {
        let payload: String = "y".repeat(4096);
        let mut r = Reply::new(200, payload.clone(), false);
        r.http11 = false;
        let bytes = encode_reply(&r, 64);
        let head = head_of(&bytes);
        assert!(head.contains(&format!("Content-Length: {}\r\n", payload.len())), "{head}");
        assert!(!head.contains("Transfer-Encoding"), "{head}");
        assert_eq!(body_of(&bytes), payload.as_bytes());
    }

    #[test]
    fn head_only_reports_length_without_body_even_past_threshold() {
        let payload: String = "z".repeat(4096);
        let mut r = Reply::new(200, payload.clone(), true);
        r.head_only = true;
        let bytes = encode_reply(&r, 64);
        let head = head_of(&bytes);
        assert!(head.contains(&format!("Content-Length: {}\r\n", payload.len())), "{head}");
        assert!(!head.contains("Transfer-Encoding"), "{head}");
        assert!(body_of(&bytes).is_empty(), "HEAD response must not carry a body");
    }

    #[test]
    fn retry_after_header_emitted_and_floored_at_one_second() {
        let r = Reply::retryable(503, "queue full", false, 2);
        assert!(head_of(&encode_reply(&r, 1024)).contains("Retry-After: 2\r\n"));
        let r = Reply::retryable(503, "queue full", false, 0);
        assert!(head_of(&encode_reply(&r, 1024)).contains("Retry-After: 1\r\n"));
        let r = Reply::error(503, "quarantined", false);
        assert!(!head_of(&encode_reply(&r, 1024)).contains("Retry-After"), "no hint by default");
    }

    #[test]
    fn allow_header_emitted_for_405() {
        let mut r = Reply::error(405, "method not allowed", true);
        r.allow = Some("GET, HEAD");
        let bytes = encode_reply(&r, 1024);
        assert!(head_of(&bytes).contains("Allow: GET, HEAD\r\n"));
    }
}
