//! Transient vs persistent overflow classification (paper §3.1).

use crate::accum;

/// Classification of one dot product at accumulator width p.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OverflowClass {
    /// Exact (wide) final value.
    pub exact: i64,
    /// The final result itself leaves the p-bit range: a true overflow no
    /// ordering can fix.
    pub persistent: bool,
    /// Overflow events under naive index-order clipped accumulation.
    pub naive_events: u32,
    /// Naive order overflowed but the final result fits: fixable by
    /// reordering (what the sorted dot product eliminates).
    pub transient: bool,
}

/// Classify a dot product per paper §3.1.
pub fn classify(prods: &[i32], p: u32) -> OverflowClass {
    let (lo, hi) = accum::acc_range(p);
    let exact = accum::exact_dot(prods);
    let (_, naive_events) = accum::clip_accumulate(prods, p);
    let persistent = exact < lo || exact > hi;
    OverflowClass {
        exact,
        persistent,
        naive_events,
        transient: naive_events > 0 && !persistent,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    #[test]
    fn paper_examples() {
        // 3 maximal 8-bit products: 48387 > 32767 -> persistent at p=16
        let c = classify(&[16129; 3], 16);
        assert!(c.persistent && !c.transient);
        // balanced: exact 0, naive order spikes -> transient
        let c = classify(&[16129, 16129, 16129, -16129, -16129, -16129], 16);
        assert!(c.transient && !c.persistent && c.naive_events > 0);
        // clean
        let c = classify(&[100, -50], 16);
        assert!(!c.transient && !c.persistent && c.naive_events == 0);
    }

    #[test]
    fn partition_prop() {
        prop::check(
            "classify-partition",
            400,
            |r: &mut Pcg32| (prop::gen_prods(r, 200, 8), 12 + r.below(12)),
            |(prods, p)| {
                let c = classify(prods, *p);
                if c.transient && c.persistent {
                    return Err("both transient and persistent".into());
                }
                if c.transient && c.naive_events == 0 {
                    return Err("transient without events".into());
                }
                let (lo, hi) = accum::acc_range(*p);
                if c.persistent != (c.exact < lo || c.exact > hi) {
                    return Err("persistent flag wrong".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn threshold_k_star() {
        // paper §3: p=32, b=8 -> overflow needs K >= 2^16 maximal products
        let prods = vec![16129i32; 100];
        assert!(!classify(&prods, 32).persistent);
        // p = 2b = 16: possible after only a few
        assert!(classify(&[16129, 16129, 16129], 16).persistent);
    }
}
