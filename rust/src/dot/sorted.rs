//! The PQS sorted dot product (paper §3.2, Algorithm 1).
//!
//! Two variants, both bit-exact against `ref.py`:
//! * `sorted1_dot` — single sorting round (what the Pallas kernel and the
//!   paper's one-round claim use): pair the largest positives with the most
//!   negative products, then push the paired sums through the p-bit
//!   accumulator in order.
//! * `sorted_full_dot` — Algorithm 1 verbatim: repeat split/sort/pair in
//!   exact temporaries until a single sign remains, then accumulate the
//!   monotone remainder with clipping.
//!
//! Pairing arithmetic is exact (|pos + neg| <= max(|pos|, |neg|) fits i32);
//! only the running accumulation is width-limited, mirroring a hardware
//! sorting network feeding a narrow accumulator (paper §6).
//!
//! ### Sorting fast paths
//!
//! Quantized partial products live in a bounded domain (|w·x| <= 127·255 <
//! 2^15 for 8-bit weights/activations), so the sorting round does not need
//! a comparison sort. `sort_asc`/`sort_desc` pick per call:
//! * **counting sort** over the observed `[min, max]` window when the span
//!   is at most [`COUNTING_SPAN_FACTOR`]× the length (emit walk stays
//!   O(len) — typical for low-bit or sparse products);
//! * **2-pass LSD radix sort** (256 buckets/pass) when the span fits 16
//!   bits — always true for 8-bit products — giving O(len) for long dots;
//! * **comparison sort** for short inputs (< [`FAST_SORT_MIN_LEN`]) or
//!   arbitrary-range values, so the fast path is never slower.
//!
//! All three produce identical sequences (values are sorted by value only),
//! which the pairing property tests below assert bit-for-bit.

use super::DotEngine;
use crate::accum::{self};

/// Minimum length before the counting/radix fast paths pay off.
const FAST_SORT_MIN_LEN: usize = 64;
/// Counting sort is used when `span <= len * COUNTING_SPAN_FACTOR`.
const COUNTING_SPAN_FACTOR: u64 = 4;

/// Ascending sort with the adaptive counting/radix/comparison strategy.
fn sort_asc(v: &mut [i32], counts: &mut Vec<u32>, tmp: &mut Vec<i32>) {
    if v.len() < FAST_SORT_MIN_LEN {
        v.sort_unstable();
    } else {
        sort_fast_asc(v, counts, tmp);
    }
}

/// Descending sort with the adaptive counting/radix/comparison strategy.
fn sort_desc(v: &mut [i32], counts: &mut Vec<u32>, tmp: &mut Vec<i32>) {
    if v.len() < FAST_SORT_MIN_LEN {
        v.sort_unstable_by(|a, b| b.cmp(a));
    } else {
        sort_fast_asc(v, counts, tmp);
        v.reverse();
    }
}

/// len >= FAST_SORT_MIN_LEN: choose counting / radix / comparison by span.
fn sort_fast_asc(v: &mut [i32], counts: &mut Vec<u32>, tmp: &mut Vec<i32>) {
    let (mut lo, mut hi) = (v[0], v[0]);
    for &x in v.iter() {
        if x < lo {
            lo = x;
        }
        if x > hi {
            hi = x;
        }
    }
    let span = (hi as i64 - lo as i64) as u64 + 1;
    if span <= (v.len() as u64).saturating_mul(COUNTING_SPAN_FACTOR) {
        counting_sort_asc(v, lo, span as usize, counts);
    } else if span <= 1 << 16 {
        radix_sort_asc(v, lo, counts, tmp);
    } else {
        v.sort_unstable();
    }
}

/// Counting sort over the dense window `[lo, lo + span)`. `counts` is
/// persistent scratch; it is left all-zero (buckets are cleared as they are
/// emitted), so reuse across calls never needs a full clear.
fn counting_sort_asc(v: &mut [i32], lo: i32, span: usize, counts: &mut Vec<u32>) {
    if counts.len() < span {
        counts.resize(span, 0);
    }
    for &x in v.iter() {
        counts[(x - lo) as usize] += 1;
    }
    let mut w = 0usize;
    for (b, slot) in counts.iter_mut().enumerate().take(span) {
        let c = *slot;
        if c > 0 {
            let val = lo + b as i32;
            for _ in 0..c {
                v[w] = val;
                w += 1;
            }
            *slot = 0;
        }
    }
    debug_assert_eq!(w, v.len());
}

/// Stable 2-pass LSD radix sort of `v` by the 16-bit key `x - lo`
/// (precondition: `hi - lo < 2^16`). 256 buckets per pass; `counts` and
/// `tmp` are persistent scratch, `counts` is left all-zero.
fn radix_sort_asc(v: &mut [i32], lo: i32, counts: &mut Vec<u32>, tmp: &mut Vec<i32>) {
    let n = v.len();
    if counts.len() < 256 {
        counts.resize(256, 0);
    }
    tmp.clear();
    tmp.resize(n, 0);
    let c = &mut counts[..256];
    // pass 1: low byte, v -> tmp
    for &x in v.iter() {
        c[((x - lo) as u16 & 0xff) as usize] += 1;
    }
    let mut sum = 0u32;
    for slot in c.iter_mut() {
        let cnt = *slot;
        *slot = sum;
        sum += cnt;
    }
    for &x in v.iter() {
        let b = ((x - lo) as u16 & 0xff) as usize;
        tmp[c[b] as usize] = x;
        c[b] += 1;
    }
    c.fill(0);
    // pass 2: high byte, tmp -> v
    for &x in tmp.iter() {
        c[((x - lo) as u16 >> 8) as usize] += 1;
    }
    let mut sum = 0u32;
    for slot in c.iter_mut() {
        let cnt = *slot;
        *slot = sum;
        sum += cnt;
    }
    for &x in tmp.iter() {
        let b = ((x - lo) as u16 >> 8) as usize;
        v[c[b] as usize] = x;
        c[b] += 1;
    }
    c.fill(0);
}

/// One PQS sorting round into `seq`: `seq[i] = pos_desc[i] + neg_asc[i]`
/// with zero padding so `sum(seq) == sum(prods)` exactly.
pub fn sorted1_pair_into(eng: &mut DotEngine, prods: &[i32], out_is_seq: bool) {
    let k = prods.len();
    let DotEngine { pos, neg, seq, counts, radix_tmp, .. } = eng;
    pos.clear();
    neg.clear();
    for &v in prods {
        if v > 0 {
            pos.push(v);
        } else if v < 0 {
            neg.push(v);
        }
    }
    // descending positives, ascending negatives; zeros pad the tails
    sort_desc(pos, counts, radix_tmp);
    sort_asc(neg, counts, radix_tmp);
    if out_is_seq {
        seq.clear();
        seq.reserve(k);
        let m = pos.len().min(neg.len());
        for i in 0..m {
            seq.push(pos[i] + neg[i]);
        }
        if pos.len() > m {
            seq.extend_from_slice(&pos[m..]);
        } else {
            seq.extend_from_slice(&neg[m..]);
        }
        // NOTE: ref.py / the Pallas kernel keep a fixed K-length sequence
        // with a zero tail; adding zero can never overflow, so dropping the
        // padding preserves both value and event count exactly (perf pass:
        // the zero tail dominated the clip scan on sparse inputs).
        let _ = k;
    }
}

/// Single-round sorted dot product through a p-bit clipping accumulator.
pub fn sorted1_dot(eng: &mut DotEngine, prods: &[i32], p: u32) -> (i64, u32) {
    sorted1_pair_into(eng, prods, true);
    let seq = std::mem::take(&mut eng.seq);
    let r = accum::clip_accumulate(&seq, p);
    eng.seq = seq;
    r
}

/// Algorithm 1 (multi-round) through a p-bit clipping accumulator.
pub fn sorted_full_dot(eng: &mut DotEngine, prods: &[i32], p: u32) -> (i64, u32) {
    let DotEngine { pos, neg, tmp: cur, counts, radix_tmp, .. } = eng;
    cur.clear();
    cur.extend(prods.iter().copied().filter(|&v| v != 0));
    loop {
        if cur.len() <= 1 {
            let r = match cur.first() {
                None => (0, 0),
                Some(&v) => accum::clip_accumulate(&[v], p),
            };
            return r;
        }
        pos.clear();
        neg.clear();
        for &v in cur.iter() {
            if v > 0 {
                pos.push(v);
            } else {
                neg.push(v);
            }
        }
        if pos.is_empty() || neg.is_empty() {
            // Single sign: monotone accumulation through the accumulator.
            // Order within a sign does not change the event count (monotone
            // prefix), but keep ref.py's order: the current buffer order.
            return accum::clip_accumulate(cur, p);
        }
        sort_desc(pos, counts, radix_tmp);
        sort_asc(neg, counts, radix_tmp);
        let m = pos.len().min(neg.len());
        cur.clear();
        for i in 0..m {
            let s = pos[i] + neg[i];
            if s != 0 {
                cur.push(s);
            }
        }
        if pos.len() > m {
            cur.extend_from_slice(&pos[m..]);
        } else if neg.len() > m {
            cur.extend_from_slice(&neg[m..]);
        }
    }
}

/// `sorted_full_dot` with early persistent-overflow exit (paper §6): once
/// the monotone accumulation clips, every remaining same-sign add would
/// also clip, so we stop. Returns `(value, events, adds_skipped)`.
pub fn sorted_full_dot_early_exit(eng: &mut DotEngine, prods: &[i32], p: u32) -> (i64, u32, usize) {
    let DotEngine { pos, neg, tmp: cur, counts, radix_tmp, .. } = eng;
    cur.clear();
    cur.extend(prods.iter().copied().filter(|&v| v != 0));
    loop {
        if cur.len() <= 1 {
            return match cur.first() {
                None => (0, 0, 0),
                Some(&v) => {
                    let (val, ev) = accum::clip_accumulate(&[v], p);
                    (val, ev, 0)
                }
            };
        }
        pos.clear();
        neg.clear();
        for &v in cur.iter() {
            if v > 0 {
                pos.push(v);
            } else {
                neg.push(v);
            }
        }
        if pos.is_empty() || neg.is_empty() {
            // monotone phase with early exit
            let (lo, hi) = accum::acc_range(p);
            let mut acc = 0i64;
            for (i, &v) in cur.iter().enumerate() {
                let t = acc + v as i64;
                if t < lo || t > hi {
                    // one event, remainder skipped (all same sign => all clip)
                    let skipped = cur.len() - i - 1;
                    return (if t < lo { lo } else { hi }, 1 + skipped as u32, skipped);
                }
                acc = t;
            }
            return (acc, 0, 0);
        }
        sort_desc(pos, counts, radix_tmp);
        sort_asc(neg, counts, radix_tmp);
        let m = pos.len().min(neg.len());
        cur.clear();
        for i in 0..m {
            let s = pos[i] + neg[i];
            if s != 0 {
                cur.push(s);
            }
        }
        if pos.len() > m {
            cur.extend_from_slice(&pos[m..]);
        } else if neg.len() > m {
            cur.extend_from_slice(&neg[m..]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accum::Policy;
    use crate::dot::classify;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    fn eng() -> DotEngine {
        DotEngine::new()
    }

    /// Reference pairing with plain comparison sorts (the seed
    /// implementation), used to prove the fast sorts change nothing.
    fn reference_pair(prods: &[i32]) -> Vec<i32> {
        let mut pos: Vec<i32> = prods.iter().copied().filter(|&v| v > 0).collect();
        let mut neg: Vec<i32> = prods.iter().copied().filter(|&v| v < 0).collect();
        pos.sort_unstable_by(|a, b| b.cmp(a));
        neg.sort_unstable();
        let m = pos.len().min(neg.len());
        let mut seq: Vec<i32> = (0..m).map(|i| pos[i] + neg[i]).collect();
        if pos.len() > m {
            seq.extend_from_slice(&pos[m..]);
        } else {
            seq.extend_from_slice(&neg[m..]);
        }
        seq
    }

    #[test]
    fn counting_sort_matches_comparison() {
        prop::check(
            "counting-sort-matches",
            200,
            |r: &mut Pcg32| {
                // narrow span forces the counting path at these lengths
                let n = 64 + r.below(200) as usize;
                r.ivec(n, -40, 40)
            },
            |v| {
                let mut a = v.clone();
                let mut b = v.clone();
                let (mut counts, mut tmp) = (Vec::new(), Vec::new());
                sort_asc(&mut a, &mut counts, &mut tmp);
                b.sort_unstable();
                if a != b {
                    return Err("ascending mismatch".into());
                }
                let mut d = v.clone();
                sort_desc(&mut d, &mut counts, &mut tmp);
                b.reverse();
                if d != b {
                    return Err("descending mismatch".into());
                }
                if counts.iter().any(|&c| c != 0) {
                    return Err("counts scratch not re-zeroed".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn radix_sort_matches_comparison() {
        prop::check(
            "radix-sort-matches",
            200,
            |r: &mut Pcg32| {
                // wide 15-bit span at modest length forces the radix path
                let n = 64 + r.below(400) as usize;
                r.ivec(n, -32385, 32385)
            },
            |v| {
                let mut a = v.clone();
                let mut b = v.clone();
                let (mut counts, mut tmp) = (Vec::new(), Vec::new());
                sort_asc(&mut a, &mut counts, &mut tmp);
                b.sort_unstable();
                if a != b {
                    return Err("ascending mismatch".into());
                }
                let mut d = v.clone();
                sort_desc(&mut d, &mut counts, &mut tmp);
                b.reverse();
                if d != b {
                    return Err("descending mismatch".into());
                }
                if counts.iter().any(|&c| c != 0) {
                    return Err("counts scratch not re-zeroed".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn wide_span_falls_back_to_comparison() {
        let mut v: Vec<i32> = (0..128).map(|i| (i * 16_777_259) ^ 0x5A5A5A5).collect();
        let mut b = v.clone();
        let (mut counts, mut tmp) = (Vec::new(), Vec::new());
        sort_asc(&mut v, &mut counts, &mut tmp);
        b.sort_unstable();
        assert_eq!(v, b);
    }

    #[test]
    fn fast_pairing_bit_identical_to_comparison_pairing() {
        // the ISSUE contract: counting/radix pairing == comparison pairing,
        // across short (comparison), narrow (counting) and wide (radix)
        // product profiles
        prop::check(
            "pairing-bit-identical",
            300,
            |r: &mut Pcg32| {
                let profile = r.below(3);
                let n = match profile {
                    0 => r.below(64) as usize,        // short: comparison
                    1 => 64 + r.below(512) as usize,  // narrow: counting
                    _ => 64 + r.below(512) as usize,  // wide: radix
                };
                let (lo, hi) = if profile == 1 { (-50, 50) } else { (-32385, 32385) };
                r.ivec(n, lo, hi)
            },
            |prods| {
                let mut e = eng();
                sorted1_pair_into(&mut e, prods, true);
                let want = reference_pair(prods);
                if e.seq != want {
                    return Err(format!(
                        "pairing diverged: len {} vs {}",
                        e.seq.len(),
                        want.len()
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn pair_preserves_sum_prop() {
        prop::check(
            "sorted1-sum-preserved",
            300,
            |r: &mut Pcg32| prop::gen_prods(r, 128, 8),
            |prods| {
                let mut e = eng();
                sorted1_pair_into(&mut e, prods, true);
                let s: i64 = e.seq.iter().map(|&v| v as i64).sum();
                let t: i64 = prods.iter().map(|&v| v as i64).sum();
                if s != t {
                    return Err(format!("{s} != {t}"));
                }
                if e.seq.len() > prods.len() {
                    return Err("length grew".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn pair_preserves_sum_long_dots() {
        // same invariant at lengths that engage the counting/radix paths
        prop::check(
            "sorted1-sum-preserved-long",
            100,
            |r: &mut Pcg32| prop::gen_prods(r, 1024, 8),
            |prods| {
                let mut e = eng();
                sorted1_pair_into(&mut e, prods, true);
                let s: i64 = e.seq.iter().map(|&v| v as i64).sum();
                let t: i64 = prods.iter().map(|&v| v as i64).sum();
                if s != t {
                    return Err(format!("{s} != {t}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn full_sorted_terminates_and_is_exact_when_fits_prop() {
        prop::check(
            "sorted-full-exact",
            500,
            |r: &mut Pcg32| (prop::gen_prods(r, 256, 8), 12 + r.below(12)),
            |(prods, p)| {
                let mut e = eng();
                let cls = classify(prods, *p);
                let (v, ev) = sorted_full_dot(&mut e, prods, *p);
                if !cls.persistent && (ev != 0 || v != cls.exact) {
                    return Err(format!("v={v} ev={ev} exact={}", cls.exact));
                }
                if cls.persistent {
                    let (lo, hi) = crate::accum::acc_range(*p);
                    let want = if cls.exact > hi { hi } else { lo };
                    if v != want {
                        return Err(format!("persistent clipped to {v} not {want}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn early_exit_matches_value() {
        prop::check(
            "early-exit-value",
            300,
            |r: &mut Pcg32| (prop::gen_prods(r, 128, 8), 12 + r.below(8)),
            |(prods, p)| {
                let mut e = eng();
                let (v1, _) = sorted_full_dot(&mut e, prods, *p);
                let mut e2 = eng();
                let (v2, _, _) = sorted_full_dot_early_exit(&mut e2, prods, *p);
                if v1 != v2 {
                    return Err(format!("{v1} != {v2}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn early_exit_skips_on_persistent() {
        let mut e = eng();
        let prods = vec![10_000i32; 64]; // hugely persistent at p=14
        let (_, _, skipped) = sorted_full_dot_early_exit(&mut e, &prods, 14);
        assert!(skipped > 50, "skipped {skipped}");
    }

    #[test]
    fn engineered_transient_resolved() {
        // mirrors python test: +3/-3 maximal products, exact sum 0
        let prods = [16129, 16129, 16129, -16129, -16129, -16129];
        let mut e = eng();
        assert_eq!(sorted1_dot(&mut e, &prods, 16), (0, 0));
        assert_eq!(sorted_full_dot(&mut e, &prods, 16), (0, 0));
        let mut d = eng();
        let (v, ev) = d.dot(&prods, 16, Policy::Clip);
        assert!(ev > 0 && v != 0);
    }

    #[test]
    fn single_sign_monotone_no_events_when_fits() {
        let prods = [5i32, 7, 11, 13];
        let mut e = eng();
        assert_eq!(sorted_full_dot(&mut e, &prods, 12), (36, 0));
    }

    #[test]
    fn zeros_and_empty() {
        let mut e = eng();
        assert_eq!(sorted_full_dot(&mut e, &[], 12), (0, 0));
        assert_eq!(sorted_full_dot(&mut e, &[0, 0, 0], 12), (0, 0));
        assert_eq!(sorted1_dot(&mut e, &[], 12), (0, 0));
        assert_eq!(sorted1_dot(&mut e, &[0], 12), (0, 0));
    }

    #[test]
    fn scratch_reuse_is_clean() {
        // running different dots back-to-back on one engine must not leak
        let mut e = eng();
        let a = sorted1_dot(&mut e, &[100, -50, 25], 16);
        let b = sorted1_dot(&mut e, &[1, 2, 3], 16);
        let c = sorted1_dot(&mut e, &[100, -50, 25], 16);
        assert_eq!(a, c);
        assert_eq!(b, (6, 0));
    }

    #[test]
    fn scratch_reuse_is_clean_across_fast_paths() {
        // alternate counting-path, radix-path and comparison-path dots on
        // one engine: persistent count/tmp scratch must never leak between
        let mut r = Pcg32::new(0xFA57);
        let narrow = r.ivec(256, -30, 30);
        let wide = r.ivec(256, -32000, 32000);
        let short = r.ivec(8, -32000, 32000);
        let mut e = eng();
        let mut fresh = || eng();
        for v in [&narrow, &wide, &short, &narrow, &wide] {
            let got = sorted1_dot(&mut e, v, 16);
            let want = sorted1_dot(&mut fresh(), v, 16);
            assert_eq!(got, want);
        }
    }
}
