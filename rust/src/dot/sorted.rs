//! The PQS sorted dot product (paper §3.2, Algorithm 1).
//!
//! Two variants, both bit-exact against `ref.py`:
//! * `sorted1_dot` — single sorting round (what the Pallas kernel and the
//!   paper's one-round claim use): pair the largest positives with the most
//!   negative products, then push the paired sums through the p-bit
//!   accumulator in order.
//! * `sorted_full_dot` — Algorithm 1 verbatim: repeat split/sort/pair in
//!   exact temporaries until a single sign remains, then accumulate the
//!   monotone remainder with clipping.
//!
//! Pairing arithmetic is exact (|pos + neg| <= max(|pos|, |neg|) fits i32);
//! only the running accumulation is width-limited, mirroring a hardware
//! sorting network feeding a narrow accumulator (paper §6).

use super::DotEngine;
use crate::accum::{self};

/// One PQS sorting round into `seq`: `seq[i] = pos_desc[i] + neg_asc[i]`
/// with zero padding so `sum(seq) == sum(prods)` exactly.
pub fn sorted1_pair_into(eng: &mut DotEngine, prods: &[i32], out_is_seq: bool) {
    let k = prods.len();
    let (pos, neg, seq) = (&mut eng.pos, &mut eng.neg, &mut eng.seq);
    pos.clear();
    neg.clear();
    for &v in prods {
        if v > 0 {
            pos.push(v);
        } else if v < 0 {
            neg.push(v);
        }
    }
    // descending positives, ascending negatives; zeros pad the tails
    pos.sort_unstable_by(|a, b| b.cmp(a));
    neg.sort_unstable();
    if out_is_seq {
        seq.clear();
        seq.reserve(k);
        let m = pos.len().min(neg.len());
        for i in 0..m {
            seq.push(pos[i] + neg[i]);
        }
        if pos.len() > m {
            seq.extend_from_slice(&pos[m..]);
        } else {
            seq.extend_from_slice(&neg[m..]);
        }
        // NOTE: ref.py / the Pallas kernel keep a fixed K-length sequence
        // with a zero tail; adding zero can never overflow, so dropping the
        // padding preserves both value and event count exactly (perf pass:
        // the zero tail dominated the clip scan on sparse inputs).
        let _ = k;
    }
}

/// Single-round sorted dot product through a p-bit clipping accumulator.
pub fn sorted1_dot(eng: &mut DotEngine, prods: &[i32], p: u32) -> (i64, u32) {
    sorted1_pair_into(eng, prods, true);
    let seq = std::mem::take(&mut eng.seq);
    let r = accum::clip_accumulate(&seq, p);
    eng.seq = seq;
    r
}

/// Algorithm 1 (multi-round) through a p-bit clipping accumulator.
pub fn sorted_full_dot(eng: &mut DotEngine, prods: &[i32], p: u32) -> (i64, u32) {
    let cur = &mut eng.tmp;
    cur.clear();
    cur.extend(prods.iter().copied().filter(|&v| v != 0));
    loop {
        if cur.len() <= 1 {
            let r = match cur.first() {
                None => (0, 0),
                Some(&v) => accum::clip_accumulate(&[v], p),
            };
            return r;
        }
        let (pos, neg) = (&mut eng.pos, &mut eng.neg);
        pos.clear();
        neg.clear();
        for &v in cur.iter() {
            if v > 0 {
                pos.push(v);
            } else {
                neg.push(v);
            }
        }
        if pos.is_empty() || neg.is_empty() {
            // Single sign: monotone accumulation through the accumulator.
            // Order within a sign does not change the event count (monotone
            // prefix), but keep ref.py's order: the current buffer order.
            return accum::clip_accumulate(cur, p);
        }
        pos.sort_unstable_by(|a, b| b.cmp(a));
        neg.sort_unstable();
        let m = pos.len().min(neg.len());
        cur.clear();
        for i in 0..m {
            let s = pos[i] + neg[i];
            if s != 0 {
                cur.push(s);
            }
        }
        if pos.len() > m {
            cur.extend_from_slice(&pos[m..]);
        } else if neg.len() > m {
            cur.extend_from_slice(&neg[m..]);
        }
    }
}

/// `sorted_full_dot` with early persistent-overflow exit (paper §6): once
/// the monotone accumulation clips, every remaining same-sign add would
/// also clip, so we stop. Returns `(value, events, adds_skipped)`.
pub fn sorted_full_dot_early_exit(eng: &mut DotEngine, prods: &[i32], p: u32) -> (i64, u32, usize) {
    let cur = &mut eng.tmp;
    cur.clear();
    cur.extend(prods.iter().copied().filter(|&v| v != 0));
    loop {
        if cur.len() <= 1 {
            return match cur.first() {
                None => (0, 0, 0),
                Some(&v) => {
                    let (val, ev) = accum::clip_accumulate(&[v], p);
                    (val, ev, 0)
                }
            };
        }
        let (pos, neg) = (&mut eng.pos, &mut eng.neg);
        pos.clear();
        neg.clear();
        for &v in cur.iter() {
            if v > 0 {
                pos.push(v);
            } else {
                neg.push(v);
            }
        }
        if pos.is_empty() || neg.is_empty() {
            // monotone phase with early exit
            let (lo, hi) = accum::acc_range(p);
            let mut acc = 0i64;
            for (i, &v) in cur.iter().enumerate() {
                let t = acc + v as i64;
                if t < lo || t > hi {
                    // one event, remainder skipped (all same sign => all clip)
                    let skipped = cur.len() - i - 1;
                    return (if t < lo { lo } else { hi }, 1 + skipped as u32, skipped);
                }
                acc = t;
            }
            return (acc, 0, 0);
        }
        pos.sort_unstable_by(|a, b| b.cmp(a));
        neg.sort_unstable();
        let m = pos.len().min(neg.len());
        cur.clear();
        for i in 0..m {
            let s = pos[i] + neg[i];
            if s != 0 {
                cur.push(s);
            }
        }
        if pos.len() > m {
            cur.extend_from_slice(&pos[m..]);
        } else if neg.len() > m {
            cur.extend_from_slice(&neg[m..]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accum::Policy;
    use crate::dot::classify;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    fn eng() -> DotEngine {
        DotEngine::new()
    }

    #[test]
    fn pair_preserves_sum_prop() {
        prop::check(
            "sorted1-sum-preserved",
            300,
            |r: &mut Pcg32| prop::gen_prods(r, 128, 8),
            |prods| {
                let mut e = eng();
                sorted1_pair_into(&mut e, prods, true);
                let s: i64 = e.seq.iter().map(|&v| v as i64).sum();
                let t: i64 = prods.iter().map(|&v| v as i64).sum();
                if s != t {
                    return Err(format!("{s} != {t}"));
                }
                if e.seq.len() > prods.len() {
                    return Err("length grew".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn full_sorted_terminates_and_is_exact_when_fits_prop() {
        prop::check(
            "sorted-full-exact",
            500,
            |r: &mut Pcg32| (prop::gen_prods(r, 256, 8), 12 + r.below(12)),
            |(prods, p)| {
                let mut e = eng();
                let cls = classify(prods, *p);
                let (v, ev) = sorted_full_dot(&mut e, prods, *p);
                if !cls.persistent && (ev != 0 || v != cls.exact) {
                    return Err(format!("v={v} ev={ev} exact={}", cls.exact));
                }
                if cls.persistent {
                    let (lo, hi) = crate::accum::acc_range(*p);
                    let want = if cls.exact > hi { hi } else { lo };
                    if v != want {
                        return Err(format!("persistent clipped to {v} not {want}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn early_exit_matches_value() {
        prop::check(
            "early-exit-value",
            300,
            |r: &mut Pcg32| (prop::gen_prods(r, 128, 8), 12 + r.below(8)),
            |(prods, p)| {
                let mut e = eng();
                let (v1, _) = sorted_full_dot(&mut e, prods, *p);
                let mut e2 = eng();
                let (v2, _, _) = sorted_full_dot_early_exit(&mut e2, prods, *p);
                if v1 != v2 {
                    return Err(format!("{v1} != {v2}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn early_exit_skips_on_persistent() {
        let mut e = eng();
        let prods = vec![10_000i32; 64]; // hugely persistent at p=14
        let (_, _, skipped) = sorted_full_dot_early_exit(&mut e, &prods, 14);
        assert!(skipped > 50, "skipped {skipped}");
    }

    #[test]
    fn engineered_transient_resolved() {
        // mirrors python test: +3/-3 maximal products, exact sum 0
        let prods = [16129, 16129, 16129, -16129, -16129, -16129];
        let mut e = eng();
        assert_eq!(sorted1_dot(&mut e, &prods, 16), (0, 0));
        assert_eq!(sorted_full_dot(&mut e, &prods, 16), (0, 0));
        let mut d = eng();
        let (v, ev) = d.dot(&prods, 16, Policy::Clip);
        assert!(ev > 0 && v != 0);
    }

    #[test]
    fn single_sign_monotone_no_events_when_fits() {
        let prods = [5i32, 7, 11, 13];
        let mut e = eng();
        assert_eq!(sorted_full_dot(&mut e, &prods, 12), (36, 0));
    }

    #[test]
    fn zeros_and_empty() {
        let mut e = eng();
        assert_eq!(sorted_full_dot(&mut e, &[], 12), (0, 0));
        assert_eq!(sorted_full_dot(&mut e, &[0, 0, 0], 12), (0, 0));
        assert_eq!(sorted1_dot(&mut e, &[], 12), (0, 0));
        assert_eq!(sorted1_dot(&mut e, &[0], 12), (0, 0));
    }

    #[test]
    fn scratch_reuse_is_clean() {
        // running different dots back-to-back on one engine must not leak
        let mut e = eng();
        let a = sorted1_dot(&mut e, &[100, -50, 25], 16);
        let b = sorted1_dot(&mut e, &[1, 2, 3], 16);
        let c = sorted1_dot(&mut e, &[100, -50, 25], 16);
        assert_eq!(a, c);
        assert_eq!(b, (6, 0));
    }
}
