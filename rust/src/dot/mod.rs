//! Dot-product engines with width-limited accumulation — the heart of the
//! PQS library (paper §3).
//!
//! `DotEngine` owns reusable scratch buffers so the hot path (millions of
//! dot products per model evaluation) is allocation-free.

pub mod classify;
pub mod sorted;
pub mod tiled;

use crate::accum::{self, Policy};

pub use classify::{classify, OverflowClass};
pub use sorted::{sorted1_pair_into, sorted_full_dot, sorted1_dot};
pub use tiled::tiled_sorted_dot;

/// Reusable scratch space for sorted dot products.
#[derive(Default)]
pub struct DotEngine {
    pub(crate) pos: Vec<i32>,
    pub(crate) neg: Vec<i32>,
    pub(crate) seq: Vec<i32>,
    pub(crate) tmp: Vec<i32>,
    /// bucket counters for the counting/radix sorting fast paths
    /// (invariant: all zero between calls)
    pub(crate) counts: Vec<u32>,
    /// ping-pong buffer for the radix sorting fast path
    pub(crate) radix_tmp: Vec<i32>,
}

impl DotEngine {
    pub fn new() -> Self {
        Self::default()
    }

    /// Evaluate one dot product (given the partial products) under `policy`
    /// with a p-bit accumulator. Returns `(value, overflow events)`.
    ///
    /// Event semantics per policy match `ref.py::dot_with_policy`:
    /// * exact — always 0 events;
    /// * clip/wrap — events in index order;
    /// * sorted1/sorted — events in the width-limited accumulation phase
    ///   (pairing runs in exact temporaries);
    /// * oracle — exact value unless persistently overflowing (then clipped
    ///   exact value, 1 event).
    pub fn dot(&mut self, prods: &[i32], p: u32, policy: Policy) -> (i64, u32) {
        match policy {
            Policy::Exact => (accum::exact_dot(prods), 0),
            Policy::Clip => accum::clip_accumulate(prods, p),
            Policy::Wrap => accum::wrap_accumulate(prods, p),
            Policy::Sorted1 => sorted::sorted1_dot(self, prods, p),
            Policy::Sorted => sorted::sorted_full_dot(self, prods, p),
            Policy::Oracle => {
                let exact = accum::exact_dot(prods);
                let (lo, hi) = accum::acc_range(p);
                if exact >= lo && exact <= hi {
                    (exact, 0)
                } else {
                    (accum::clamp(exact, p), 1)
                }
            }
        }
    }

    /// Compute partial products `w[k]*x[k]` into the provided buffer.
    #[inline]
    pub fn products_into(w: &[i32], x: &[i32], out: &mut Vec<i32>) {
        debug_assert_eq!(w.len(), x.len());
        out.clear();
        out.extend(w.iter().zip(x).map(|(&a, &b)| a * b));
    }

    /// Convenience: full dot product from weight/activation vectors.
    pub fn dot_wx(&mut self, w: &[i32], x: &[i32], p: u32, policy: Policy) -> (i64, u32) {
        Self::products_into(w, x, &mut self.tmp);
        let prods = std::mem::take(&mut self.tmp);
        let r = self.dot(&prods, p, policy);
        self.tmp = prods;
        self.tmp.clear();
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    #[test]
    fn exact_is_sum() {
        let mut e = DotEngine::new();
        assert_eq!(e.dot(&[1, 2, 3], 16, Policy::Exact), (6, 0));
        assert_eq!(e.dot(&[], 16, Policy::Exact), (0, 0));
    }

    #[test]
    fn oracle_resolves_transients() {
        let mut e = DotEngine::new();
        // transient: exact sum 0 but naive order spikes
        let prods = [16129, 16129, 16129, -16129, -16129, -16129];
        assert_eq!(e.dot(&prods, 16, Policy::Oracle), (0, 0));
        let (v, ev) = e.dot(&prods, 16, Policy::Clip);
        assert!(ev > 0 && v != 0);
        // persistent: clipped exact
        let prods = [16129i32; 3];
        assert_eq!(e.dot(&prods, 16, Policy::Oracle), (32767, 1));
    }

    #[test]
    fn dot_wx_matches_manual_products() {
        let mut e = DotEngine::new();
        let w = [2, -3, 4];
        let x = [5, 6, -7];
        let prods = [10, -18, -28];
        for pol in Policy::ALL {
            assert_eq!(e.dot_wx(&w, &x, 14, pol), e.dot(&prods, 14, pol), "{pol:?}");
        }
    }

    #[test]
    fn all_policies_agree_on_wide_accumulator_prop() {
        prop::check(
            "policies-agree-wide",
            200,
            |r: &mut Pcg32| prop::gen_prods(r, 200, 8),
            |prods| {
                let mut e = DotEngine::new();
                let exact = accum::exact_dot(prods);
                for pol in Policy::ALL {
                    let (v, ev) = e.dot(prods, 40, pol);
                    if v != exact {
                        return Err(format!("{pol:?}: {v} != {exact}"));
                    }
                    if ev != 0 {
                        return Err(format!("{pol:?}: events at p=40"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn sorted_policies_match_exact_when_no_persistent_prop() {
        prop::check(
            "sorted-resolves",
            300,
            |r: &mut Pcg32| (prop::gen_prods(r, 200, 8), 13 + r.below(8)),
            |(prods, p)| {
                let mut e = DotEngine::new();
                let cls = classify(prods, *p);
                let (v, ev) = e.dot(prods, *p, Policy::Sorted);
                if !cls.persistent {
                    if ev != 0 {
                        return Err(format!("sorted had {ev} events without persistent overflow"));
                    }
                    if v != cls.exact {
                        return Err(format!("sorted {v} != exact {}", cls.exact));
                    }
                }
                Ok(())
            },
        );
    }
}
