//! Tiled sorted dot product (paper §6, Software Scheduling).
//!
//! Tiling splits a length-K dot product into K/t independent chunks so that
//! cache-blocked matmul schedules (and bounded hardware sorting networks)
//! can be used — but the sorting round then only sees the products inside
//! one tile. This module reproduces the paper's study: with tile size
//! k=256, PQS still eliminates ~99% of transient overflows in MobileNetV2.
//!
//! Semantics: each tile is sorted+paired independently (exact temporaries);
//! the paired sequences are pushed tile-after-tile through the *single*
//! running p-bit accumulator.
//!
//! ### Fused per-tile histogram pairing
//! Tiles are short (the paper studies k=256), so the adaptive
//! counting/radix/comparison gate inside `sorted1_pair_into` mostly
//! resolves to two comparison sorts plus a pairing pass plus a sequence
//! scan. When the tile's observed value window is narrow (the common case
//! for low-bit quantized products), [`tiled_sorted_dot`] instead builds one
//! counting-sort histogram of the tile and *emits the paired sequence
//! straight out of the bucket walk into the running accumulator* — no
//! sorts, no pos/neg buffers, no materialized sequence. The emitted order
//! is exactly `pos_desc[i] + neg_asc[i]` followed by the leftover
//! single-sign tail in sorted order, so values and overflow-event counts
//! are bit-identical to the sorted pairing (property-tested below). Tiles
//! whose span is too wide for the bucket walk to pay off fall back to
//! `sorted1_pair_into`.

use super::sorted::sorted1_pair_into;
use super::DotEngine;
use crate::accum;

/// The fused histogram walk costs O(span); fuse only when the observed
/// value window is at most this many times the tile length…
const FUSED_SPAN_FACTOR: u64 = 4;
/// …with a floor so short tiles with modest spans (where `sorted1_pair_into`
/// would fall back to comparison sorts) still take the fused path. The floor
/// bounds the worst-case bucket walk per tile to ~a comparison sort of a
/// few dozen elements, so fusing is never much slower than the gate it
/// replaces.
const FUSED_SPAN_MIN: u64 = 256;

/// Tiled single-round sorted dot product. `tile == 0` or `tile >= K` means
/// one full-width tile (identical to `sorted1_dot`).
/// Returns `(value, overflow events)`.
pub fn tiled_sorted_dot(eng: &mut DotEngine, prods: &[i32], p: u32, tile: usize) -> (i64, u32) {
    let k = prods.len();
    let tile = if tile == 0 { k.max(1) } else { tile };
    let (lo, hi) = accum::acc_range(p);
    let mut acc = 0i64;
    let mut ovf = 0u32;
    let mut start = 0;
    while start < k {
        let end = (start + tile).min(k);
        let t = &prods[start..end];
        if !fused_tile_accumulate(&mut eng.counts, t, lo, hi, &mut acc, &mut ovf) {
            // wide-span tile: the general sorted pairing
            sorted1_pair_into(eng, t, true);
            for &v in &eng.seq {
                let s = acc + v as i64;
                acc = if s < lo {
                    ovf += 1;
                    lo
                } else if s > hi {
                    ovf += 1;
                    hi
                } else {
                    s
                };
            }
        }
        start = end;
    }
    (acc, ovf)
}

/// Fused counting-sort pairing for one tile: histogram the nonzero values,
/// then walk positives downward and negatives upward, pushing each paired
/// sum (and the single-sign tail) straight through the clipped accumulator.
/// Returns `false` — leaving `counts`, `acc` and `ovf` untouched — when the
/// value span is too wide for the walk to pay off. `counts` is persistent
/// scratch and is left all-zero (the walk consumes every bucket it filled).
fn fused_tile_accumulate(
    counts: &mut Vec<u32>,
    tile: &[i32],
    lo: i64,
    hi: i64,
    acc: &mut i64,
    ovf: &mut u32,
) -> bool {
    let mut vmin = i32::MAX;
    let mut vmax = i32::MIN;
    let mut npos = 0u32;
    let mut nneg = 0u32;
    for &v in tile {
        if v > 0 {
            npos += 1;
        } else if v < 0 {
            nneg += 1;
        } else {
            continue;
        }
        if v < vmin {
            vmin = v;
        }
        if v > vmax {
            vmax = v;
        }
    }
    if npos == 0 && nneg == 0 {
        return true; // all zeros: the pairing contributes nothing
    }
    let span = (vmax as i64 - vmin as i64) as u64 + 1;
    if span > (tile.len() as u64).saturating_mul(FUSED_SPAN_FACTOR).max(FUSED_SPAN_MIN) {
        return false;
    }
    let span = span as usize;
    if counts.len() < span {
        counts.resize(span, 0);
    }
    for &v in tile {
        if v != 0 {
            counts[(v - vmin) as usize] += 1;
        }
    }
    let mut clip = |s: i32| {
        let t = *acc + s as i64;
        *acc = if t < lo {
            *ovf += 1;
            lo
        } else if t > hi {
            *ovf += 1;
            hi
        } else {
            t
        };
    };
    // paired phase: i-th largest positive + i-th most-negative value. The
    // scans can never cross zero: `npos > 0` guarantees a positive bucket
    // below `pcur`, `nneg > 0` a negative bucket above `ncur`.
    let mut pcur = vmax;
    let mut ncur = vmin;
    while npos > 0 && nneg > 0 {
        while counts[(pcur - vmin) as usize] == 0 {
            pcur -= 1;
        }
        while counts[(ncur - vmin) as usize] == 0 {
            ncur += 1;
        }
        let m = counts[(pcur - vmin) as usize].min(counts[(ncur - vmin) as usize]);
        let s = pcur + ncur;
        for _ in 0..m {
            clip(s);
        }
        counts[(pcur - vmin) as usize] -= m;
        counts[(ncur - vmin) as usize] -= m;
        npos -= m;
        nneg -= m;
    }
    // single-sign tail, still in pairing order: descending positives or
    // ascending negatives
    while npos > 0 {
        while counts[(pcur - vmin) as usize] == 0 {
            pcur -= 1;
        }
        let c = counts[(pcur - vmin) as usize];
        for _ in 0..c {
            clip(pcur);
        }
        counts[(pcur - vmin) as usize] = 0;
        npos -= c;
    }
    while nneg > 0 {
        while counts[(ncur - vmin) as usize] == 0 {
            ncur += 1;
        }
        let c = counts[(ncur - vmin) as usize];
        for _ in 0..c {
            clip(ncur);
        }
        counts[(ncur - vmin) as usize] = 0;
        nneg -= c;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dot::sorted::sorted1_dot;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    #[test]
    fn full_tile_equals_sorted1() {
        prop::check(
            "tiled-full-is-sorted1",
            200,
            |r: &mut Pcg32| (prop::gen_prods(r, 128, 8), 12 + r.below(10)),
            |(prods, p)| {
                let mut a = DotEngine::new();
                let mut b = DotEngine::new();
                let t = tiled_sorted_dot(&mut a, prods, *p, 0);
                let s = sorted1_dot(&mut b, prods, *p);
                if t != s {
                    return Err(format!("{t:?} != {s:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn tiled_value_exact_when_no_events() {
        prop::check(
            "tiled-clean-exact",
            300,
            |r: &mut Pcg32| {
                let prods = prop::gen_prods(r, 200, 8);
                let tile = [8usize, 16, 64][r.below(3) as usize];
                (prods, 14 + r.below(8), tile)
            },
            |(prods, p, tile)| {
                let mut e = DotEngine::new();
                let (v, ev) = tiled_sorted_dot(&mut e, prods, *p, *tile);
                let exact: i64 = prods.iter().map(|&x| x as i64).sum();
                if ev == 0 && v != exact {
                    return Err(format!("clean but {v} != {exact}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn smaller_tiles_weaker_or_equal() {
        // An engineered case where tiling misses the cancellation: large
        // positives in tile 1, large negatives in tile 2.
        let mut prods = vec![16000i32; 8];
        prods.extend(vec![-16000i32; 8]);
        let mut e = DotEngine::new();
        let (v_full, ev_full) = tiled_sorted_dot(&mut e, &prods, 16, 0);
        assert_eq!((v_full, ev_full), (0, 0));
        let (_, ev_tiled) = tiled_sorted_dot(&mut e, &prods, 16, 8);
        assert!(ev_tiled > 0, "tile=8 should overflow inside first tile");
    }

    #[test]
    fn tile_one_is_naive_clip() {
        // tile=1 degenerates to index-order clipped accumulation
        let prods = [30000i32, -20000, 25000, -30000];
        let mut e = DotEngine::new();
        let a = tiled_sorted_dot(&mut e, &prods, 16, 1);
        let b = crate::accum::clip_accumulate(&prods, 16);
        assert_eq!(a, b);
    }

    #[test]
    fn wide_accumulator_matches_single_tile_reference_for_any_tile() {
        // 8-bit products are bounded by 127*128 = 16256, and at most 256 of
        // them sum to < 2^23 in magnitude — so a p=28 accumulator can never
        // clip and EVERY tile size must return the single-tile reference
        // value (= the exact sum) with zero overflow events
        prop::check(
            "tiled-wide-p-matches-reference",
            300,
            |r: &mut Pcg32| {
                let prods = prop::gen_prods(r, 256, 8);
                let tile = 1 + r.below(300) as usize;
                (prods, tile)
            },
            |(prods, tile)| {
                let mut a = DotEngine::new();
                let mut b = DotEngine::new();
                let (v, ev) = tiled_sorted_dot(&mut a, prods, 28, *tile);
                let (want, ev_ref) = tiled_sorted_dot(&mut b, prods, 28, 0);
                let exact: i64 = prods.iter().map(|&x| x as i64).sum();
                if ev != 0 || ev_ref != 0 {
                    return Err(format!("wide p must be clean, events {ev}/{ev_ref}"));
                }
                if v != want || v != exact {
                    return Err(format!("tile {tile}: {v} != reference {want} / exact {exact}"));
                }
                Ok(())
            },
        );
    }

    /// The pre-fusion implementation: per tile, `sorted1_pair_into` + a
    /// scan of the materialized sequence. The fused histogram must match
    /// this bit-for-bit (value AND event count).
    fn reference_tiled(prods: &[i32], p: u32, tile: usize) -> (i64, u32) {
        let k = prods.len();
        let tile = if tile == 0 { k.max(1) } else { tile };
        let (lo, hi) = crate::accum::acc_range(p);
        let mut eng = DotEngine::new();
        let mut acc = 0i64;
        let mut ovf = 0u32;
        let mut start = 0;
        while start < k {
            let end = (start + tile).min(k);
            sorted1_pair_into(&mut eng, &prods[start..end], true);
            for &v in &eng.seq {
                let t = acc + v as i64;
                acc = if t < lo {
                    ovf += 1;
                    lo
                } else if t > hi {
                    ovf += 1;
                    hi
                } else {
                    t
                };
            }
            start = end;
        }
        (acc, ovf)
    }

    #[test]
    fn fused_histogram_bit_identical_to_sorted_pairing() {
        // the ISSUE contract: random bounded-domain products across value
        // profiles that hit the fused path (narrow span), the fallback
        // (wide span + short tiles) and the boundary between them
        prop::check(
            "tiled-fused-bit-identical",
            400,
            |r: &mut Pcg32| {
                let len = 1 + r.below(512) as usize;
                let bound = [8i32, 40, 500, 5000, 32385][r.below(5) as usize];
                let prods = r.ivec(len, -bound, bound);
                let tile = [1usize, 3, 8, 32, 64, 256, 0][r.below(7) as usize];
                (prods, 10 + r.below(14), tile)
            },
            |(prods, p, tile)| {
                let mut e = DotEngine::new();
                let got = tiled_sorted_dot(&mut e, prods, *p, *tile);
                let want = reference_tiled(prods, *p, *tile);
                if got != want {
                    return Err(format!(
                        "fused {got:?} != reference {want:?} (len {}, tile {tile}, p {p})",
                        prods.len()
                    ));
                }
                if e.counts.iter().any(|&c| c != 0) {
                    return Err("fused walk left the counts scratch dirty".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fused_histogram_handles_degenerate_tiles() {
        let mut e = DotEngine::new();
        // all zeros, single signs, zero-interleaved, span-1
        for (prods, tile) in [
            (vec![0i32; 16], 4usize),
            (vec![7i32; 16], 4),
            (vec![-7i32; 16], 4),
            (vec![5, 0, -5, 0, 5, 0, -5, 0], 3),
            (vec![1, -1, 1, -1], 2),
        ] {
            for p in [8u32, 12, 16] {
                let got = tiled_sorted_dot(&mut e, &prods, p, tile);
                let want = reference_tiled(&prods, p, tile);
                assert_eq!(got, want, "prods {prods:?} tile {tile} p {p}");
            }
        }
    }

    #[test]
    fn overflow_events_monotone_nonincreasing_in_p() {
        // the paired sequence depends only on (prods, tile), never on p, so
        // widening the accumulator can only remove clip events — overflow
        // counts must fall monotonically as p grows
        prop::check(
            "tiled-events-monotone-in-p",
            200,
            |r: &mut Pcg32| {
                let prods = prop::gen_prods(r, 192, 8);
                let tile = [1usize, 4, 16, 64, 0][r.below(5) as usize];
                (prods, tile)
            },
            |(prods, tile)| {
                let mut e = DotEngine::new();
                let mut prev = u32::MAX;
                for p in 8..=24 {
                    let (_, ev) = tiled_sorted_dot(&mut e, prods, p, *tile);
                    if ev > prev {
                        return Err(format!("events grew {prev} -> {ev} at p={p} tile={tile}"));
                    }
                    prev = ev;
                }
                Ok(())
            },
        );
    }
}
