//! Tiled sorted dot product (paper §6, Software Scheduling).
//!
//! Tiling splits a length-K dot product into K/t independent chunks so that
//! cache-blocked matmul schedules (and bounded hardware sorting networks)
//! can be used — but the sorting round then only sees the products inside
//! one tile. This module reproduces the paper's study: with tile size
//! k=256, PQS still eliminates ~99% of transient overflows in MobileNetV2.
//!
//! Semantics: each tile is sorted+paired independently (exact temporaries);
//! the paired sequences are pushed tile-after-tile through the *single*
//! running p-bit accumulator.

use super::sorted::sorted1_pair_into;
use super::DotEngine;
use crate::accum;

/// Tiled single-round sorted dot product. `tile == 0` or `tile >= K` means
/// one full-width tile (identical to `sorted1_dot`).
/// Returns `(value, overflow events)`.
pub fn tiled_sorted_dot(eng: &mut DotEngine, prods: &[i32], p: u32, tile: usize) -> (i64, u32) {
    let k = prods.len();
    let tile = if tile == 0 { k.max(1) } else { tile };
    let (lo, hi) = accum::acc_range(p);
    let mut acc = 0i64;
    let mut ovf = 0u32;
    let mut start = 0;
    while start < k {
        let end = (start + tile).min(k);
        sorted1_pair_into(eng, &prods[start..end], true);
        for &v in &eng.seq {
            let t = acc + v as i64;
            acc = if t < lo {
                ovf += 1;
                lo
            } else if t > hi {
                ovf += 1;
                hi
            } else {
                t
            };
        }
        start = end;
    }
    (acc, ovf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dot::sorted::sorted1_dot;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    #[test]
    fn full_tile_equals_sorted1() {
        prop::check(
            "tiled-full-is-sorted1",
            200,
            |r: &mut Pcg32| (prop::gen_prods(r, 128, 8), 12 + r.below(10)),
            |(prods, p)| {
                let mut a = DotEngine::new();
                let mut b = DotEngine::new();
                let t = tiled_sorted_dot(&mut a, prods, *p, 0);
                let s = sorted1_dot(&mut b, prods, *p);
                if t != s {
                    return Err(format!("{t:?} != {s:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn tiled_value_exact_when_no_events() {
        prop::check(
            "tiled-clean-exact",
            300,
            |r: &mut Pcg32| {
                let prods = prop::gen_prods(r, 200, 8);
                let tile = [8usize, 16, 64][r.below(3) as usize];
                (prods, 14 + r.below(8), tile)
            },
            |(prods, p, tile)| {
                let mut e = DotEngine::new();
                let (v, ev) = tiled_sorted_dot(&mut e, prods, *p, *tile);
                let exact: i64 = prods.iter().map(|&x| x as i64).sum();
                if ev == 0 && v != exact {
                    return Err(format!("clean but {v} != {exact}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn smaller_tiles_weaker_or_equal() {
        // An engineered case where tiling misses the cancellation: large
        // positives in tile 1, large negatives in tile 2.
        let mut prods = vec![16000i32; 8];
        prods.extend(vec![-16000i32; 8]);
        let mut e = DotEngine::new();
        let (v_full, ev_full) = tiled_sorted_dot(&mut e, &prods, 16, 0);
        assert_eq!((v_full, ev_full), (0, 0));
        let (_, ev_tiled) = tiled_sorted_dot(&mut e, &prods, 16, 8);
        assert!(ev_tiled > 0, "tile=8 should overflow inside first tile");
    }

    #[test]
    fn tile_one_is_naive_clip() {
        // tile=1 degenerates to index-order clipped accumulation
        let prods = [30000i32, -20000, 25000, -30000];
        let mut e = DotEngine::new();
        let a = tiled_sorted_dot(&mut e, &prods, 16, 1);
        let b = crate::accum::clip_accumulate(&prods, 16);
        assert_eq!(a, b);
    }

    #[test]
    fn wide_accumulator_matches_single_tile_reference_for_any_tile() {
        // 8-bit products are bounded by 127*128 = 16256, and at most 256 of
        // them sum to < 2^23 in magnitude — so a p=28 accumulator can never
        // clip and EVERY tile size must return the single-tile reference
        // value (= the exact sum) with zero overflow events
        prop::check(
            "tiled-wide-p-matches-reference",
            300,
            |r: &mut Pcg32| {
                let prods = prop::gen_prods(r, 256, 8);
                let tile = 1 + r.below(300) as usize;
                (prods, tile)
            },
            |(prods, tile)| {
                let mut a = DotEngine::new();
                let mut b = DotEngine::new();
                let (v, ev) = tiled_sorted_dot(&mut a, prods, 28, *tile);
                let (want, ev_ref) = tiled_sorted_dot(&mut b, prods, 28, 0);
                let exact: i64 = prods.iter().map(|&x| x as i64).sum();
                if ev != 0 || ev_ref != 0 {
                    return Err(format!("wide p must be clean, events {ev}/{ev_ref}"));
                }
                if v != want || v != exact {
                    return Err(format!("tile {tile}: {v} != reference {want} / exact {exact}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn overflow_events_monotone_nonincreasing_in_p() {
        // the paired sequence depends only on (prods, tile), never on p, so
        // widening the accumulator can only remove clip events — overflow
        // counts must fall monotonically as p grows
        prop::check(
            "tiled-events-monotone-in-p",
            200,
            |r: &mut Pcg32| {
                let prods = prop::gen_prods(r, 192, 8);
                let tile = [1usize, 4, 16, 64, 0][r.below(5) as usize];
                (prods, tile)
            },
            |(prods, tile)| {
                let mut e = DotEngine::new();
                let mut prev = u32::MAX;
                for p in 8..=24 {
                    let (_, ev) = tiled_sorted_dot(&mut e, prods, p, *tile);
                    if ev > prev {
                        return Err(format!("events grew {prev} -> {ev} at p={p} tile={tile}"));
                    }
                    prev = ev;
                }
                Ok(())
            },
        );
    }
}
