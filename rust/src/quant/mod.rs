//! Uniform per-tensor quantization (paper §2.1, Eq. 1–4).
//!
//! Mirrors `python/compile/quantize.py` bit-for-bit:
//! * weights: symmetric signed b-bit, offset 0, scale = max|W|/(2^(b-1)-1),
//!   clamped to ±(2^(b-1)-1);
//! * activations: affine per Eq. (1), range [-2^(b-1), 2^(b-1)-1];
//! * rounding is **round-half-to-even** in f32 precision, matching
//!   `np.round` on float32 arrays (NumPy weak scalar promotion keeps the
//!   division in f32). This is what makes the exported goldens bit-exact.

/// Quantization parameters for one tensor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QParams {
    pub scale: f32,
    pub offset: i32,
    pub bits: u8,
}

impl QParams {
    pub fn weight(scale: f32, bits: u8) -> Self {
        QParams { scale, offset: 0, bits }
    }

    /// Signed integer range for this bitwidth.
    pub fn qrange(&self) -> (i32, i32) {
        if self.offset == 0 {
            // symmetric weights use ±(2^(b-1)-1)
            let m = (1i32 << (self.bits - 1)) - 1;
            (-m, m)
        } else {
            (-(1i32 << (self.bits - 1)), (1i32 << (self.bits - 1)) - 1)
        }
    }
}

/// Round half to even at f32 precision (numpy `np.round` semantics).
#[inline]
pub fn round_half_even(x: f32) -> f32 {
    let r = x.round(); // half away from zero
    if (x - x.trunc()).abs() == 0.5 {
        // tie: pick the even neighbour
        if r % 2.0 == 0.0 {
            r
        } else {
            r - (r - x).signum()
        }
    } else {
        r
    }
}

/// Symmetric weight qparams from data (max-abs scaling).
pub fn weight_qparams(w: &[f32], bits: u8) -> QParams {
    let amax = w.iter().fold(0f32, |m, &v| m.max(v.abs()));
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    QParams::weight(if amax > 0.0 { amax / qmax } else { 1.0 }, bits)
}

/// Affine activation qparams per Eq. (1); `lo` is clamped to <= 0 so zero is
/// exactly representable.
pub fn act_qparams(lo: f32, hi: f32, bits: u8) -> QParams {
    let lo = lo.min(0.0);
    let hi = hi.max(lo + 1e-8);
    let scale = (hi - lo) / (((1u32 << bits) - 1) as f32);
    let offset = -(1i32 << (bits - 1)) - round_half_even(lo / scale) as i32;
    QParams { scale, offset, bits }
}

/// Quantize one value: `round(x/s) + o`, clamped into the signed range.
#[inline]
pub fn quantize(x: f32, qp: &QParams) -> i32 {
    let (lo, hi) = qp.qrange();
    let q = round_half_even(x / qp.scale) as i64 + qp.offset as i64;
    q.clamp(lo as i64, hi as i64) as i32
}

/// Dequantize per Eq. (2): `s * (q - o)`.
#[inline]
pub fn dequantize(q: i32, qp: &QParams) -> f32 {
    qp.scale * (q - qp.offset) as f32
}

/// Quantize into the *offset-free* integer domain the accumulator sees:
/// `q~ = x_q - o_x = clamp(round(x/s), qlo - o, qhi - o)`.
///
/// This is the standard integer-kernel formulation when o_w = 0 (TFLite /
/// CMSIS-NN): the dot product accumulates `w_q * (x_q - o_x)` directly and
/// the dequantization is simply `s_w * s_x * acc + bias` — the huge
/// `o_x * sum(w)` constant never transits the narrow accumulator. Products
/// still fit the paper's 2b-bit product model (127*255 = 32385 < 2^15).
/// For ReLU-positive layers (o = -2^(b-1)) the window is [0, 2^b - 1].
pub fn quantize_centered_slice_into(xs: &[f32], qp: &QParams, out: &mut Vec<i32>) {
    out.clear();
    out.reserve(xs.len());
    let (qlo, qhi) = qp.qrange();
    let (lo, hi) = ((qlo - qp.offset) as i64, (qhi - qp.offset) as i64);
    for &x in xs {
        let q = round_half_even(x / qp.scale) as i64;
        out.push(q.clamp(lo, hi) as i32);
    }
}

/// Centered quantization of a single value (see `quantize_centered_slice_into`).
#[inline]
pub fn quantize_centered(x: f32, qp: &QParams) -> i32 {
    let (qlo, qhi) = qp.qrange();
    let q = round_half_even(x / qp.scale) as i64;
    q.clamp((qlo - qp.offset) as i64, (qhi - qp.offset) as i64) as i32
}

/// Quantize a slice into the provided buffer (hot-path friendly).
pub fn quantize_slice_into(xs: &[f32], qp: &QParams, out: &mut Vec<i32>) {
    out.clear();
    out.reserve(xs.len());
    let (lo, hi) = qp.qrange();
    // NOTE: true division, not multiply-by-reciprocal — f32 bit-parity with
    // numpy's `np.round(x / s)` requires the identical operation.
    for &x in xs {
        let q = round_half_even(x / qp.scale) as i64 + qp.offset as i64;
        out.push(q.clamp(lo as i64, hi as i64) as i32);
    }
}

/// Quantize a slice (allocating convenience wrapper).
pub fn quantize_slice(xs: &[f32], qp: &QParams) -> Vec<i32> {
    let mut out = Vec::new();
    quantize_slice_into(xs, qp, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    #[test]
    fn round_half_even_ties() {
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(-2.5), -2.0);
        assert_eq!(round_half_even(0.4999), 0.0);
        assert_eq!(round_half_even(63.5), 64.0); // the test_quantize.py case
    }

    #[test]
    fn weight_symmetric_matches_python() {
        // mirrors python test: [-1, 0.5, 1] at 8 bits -> [-127, 64, 127]
        let w = [-1.0f32, 0.5, 1.0];
        let qp = weight_qparams(&w, 8);
        let q: Vec<i32> = w.iter().map(|&x| quantize(x, &qp)).collect();
        assert_eq!(q, vec![-127, 64, 127]);
        assert_eq!(qp.offset, 0);
    }

    #[test]
    fn act_zero_maps_exactly() {
        let qp = act_qparams(-0.3, 2.1, 8);
        let q0 = quantize(0.0, &qp);
        let back = dequantize(q0, &qp);
        assert!(back.abs() <= qp.scale * 0.51, "{back}");
    }

    #[test]
    fn act_values_in_range_prop() {
        prop::check(
            "act-range",
            200,
            |r: &mut Pcg32| {
                let lo = -(r.f32() * 5.0);
                let hi = r.f32() * 8.0 + 0.1;
                let bits = [4u8, 6, 8][r.below(3) as usize];
                let x = (r.f32() * (hi - lo) + lo).clamp(lo, hi);
                (lo, hi, bits, x)
            },
            |&(lo, hi, bits, x)| {
                let qp = act_qparams(lo, hi, bits);
                let q = quantize(x, &qp);
                let (qlo, qhi) = qp.qrange();
                if q < qlo || q > qhi {
                    return Err(format!("q {q} out of [{qlo},{qhi}]"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn roundtrip_error_bounded_prop() {
        prop::check(
            "quant-roundtrip",
            200,
            |r: &mut Pcg32| {
                let bits = [5u8, 8][r.below(2) as usize];
                let w: Vec<f32> = (0..16).map(|_| (r.f32() - 0.5) * 4.0).collect();
                (bits, w)
            },
            |(bits, w)| {
                let qp = weight_qparams(w, *bits);
                for &x in w {
                    let back = dequantize(quantize(x, &qp), &qp);
                    if (back - x).abs() > qp.scale * 0.5 + 1e-5 {
                        return Err(format!("{x} -> {back} (scale {})", qp.scale));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn centered_equals_shifted() {
        // q~ must equal quantize(x) - offset wherever no clamping occurs,
        // and respect the shifted window everywhere
        let qp = act_qparams(-0.5, 2.0, 8);
        let xs: Vec<f32> = (0..200).map(|i| -1.0 + 0.02 * i as f32).collect();
        let mut c = Vec::new();
        quantize_centered_slice_into(&xs, &qp, &mut c);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(c[i], quantize(x, &qp) - qp.offset, "x={x}");
        }
    }

    #[test]
    fn centered_relu_window_is_unsigned() {
        let qp = act_qparams(0.0, 1.0, 8); // o = -128
        assert_eq!(quantize_centered(0.0, &qp), 0);
        assert_eq!(quantize_centered(1.0, &qp), 255);
        assert_eq!(quantize_centered(-5.0, &qp), 0);
        assert_eq!(quantize_centered(99.0, &qp), 255);
    }

    #[test]
    fn quantize_slice_matches_scalar() {
        let qp = act_qparams(-1.0, 3.0, 8);
        let xs: Vec<f32> = (0..100).map(|i| -1.0 + 0.04 * i as f32).collect();
        let v = quantize_slice(&xs, &qp);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(v[i], quantize(x, &qp));
        }
    }
}
