//! Overflow statistics collection (paper §3.1 and §5.0.1 — the
//! "library for analyzing overflows").
//!
//! Every dot product evaluated by the engine can be classified as clean,
//! transient (naive order overflows but the exact result fits) or
//! persistent (the result itself cannot fit). Reports aggregate per layer
//! and over a whole evaluation.

/// Buckets of the per-dot required-width histogram: index = the minimal
/// signed accumulator width (`accum::bits_for_value`) of a dot's EXACT
/// value, clamped into the last bucket. 8-bit products over dots of
/// length <= 65535 (`u16` sparse columns) never need more than 33 bits,
/// so 40 buckets leave headroom.
pub const BITS_HIST_BUCKETS: usize = 40;

/// Counters over a set of dot products at one accumulator width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OverflowStats {
    /// dot products evaluated
    pub dots: u64,
    /// dots whose naive (index-order) accumulation had >= 1 overflow event
    pub naive_event_dots: u64,
    /// total naive overflow events
    pub naive_events: u64,
    /// dots with a transient overflow (naive events but exact fits)
    pub transient_dots: u64,
    /// dots with a persistent overflow (exact result out of range)
    pub persistent_dots: u64,
    /// dots where the *selected policy* still had >= 1 event
    pub policy_event_dots: u64,
    /// partial products processed (dot lengths summed, zeros skipped)
    pub products: u64,
    /// histogram of the accumulator width each dot requires to run
    /// *event-free under the engine's configured policy* (`bits_hist[p]`
    /// = dots needing exactly `p` signed bits): the final exact value's
    /// width for the sorting/exact policies, the index-order prefix
    /// extremes for `Clip`/`Wrap` (see `nn::engine`'s stats path).
    /// The calibration planner (`crate::plan`) binary-searches it for
    /// the smallest width within an overflow budget.
    pub bits_hist: [u64; BITS_HIST_BUCKETS],
}

impl Default for OverflowStats {
    fn default() -> Self {
        OverflowStats {
            dots: 0,
            naive_event_dots: 0,
            naive_events: 0,
            transient_dots: 0,
            persistent_dots: 0,
            policy_event_dots: 0,
            products: 0,
            bits_hist: [0; BITS_HIST_BUCKETS],
        }
    }
}

impl OverflowStats {
    pub fn merge(&mut self, o: &OverflowStats) {
        self.dots += o.dots;
        self.naive_event_dots += o.naive_event_dots;
        self.naive_events += o.naive_events;
        self.transient_dots += o.transient_dots;
        self.persistent_dots += o.persistent_dots;
        self.policy_event_dots += o.policy_event_dots;
        self.products += o.products;
        for (a, b) in self.bits_hist.iter_mut().zip(o.bits_hist.iter()) {
            *a += *b;
        }
    }

    /// Record that one dot's exact value needs `bits` signed accumulator
    /// bits (see [`crate::accum::bits_for_value`]).
    #[inline]
    pub fn record_required_bits(&mut self, bits: u32) {
        self.bits_hist[(bits as usize).min(BITS_HIST_BUCKETS - 1)] += 1;
    }

    /// Dots recorded in the required-width histogram.
    pub fn hist_dots(&self) -> u64 {
        self.bits_hist.iter().sum()
    }

    /// Widest requirement observed (0 when the histogram is empty).
    pub fn max_required_bits(&self) -> u32 {
        self.bits_hist
            .iter()
            .rposition(|&c| c > 0)
            .map(|p| p as u32)
            .unwrap_or(0)
    }

    /// Dots whose recorded requirement does NOT fit a `p`-bit accumulator
    /// (i.e. would overflow at width `p` under the policy the histogram
    /// was collected for).
    pub fn dots_over_width(&self, p: u32) -> u64 {
        self.bits_hist.iter().skip(p as usize + 1).sum()
    }

    /// Smallest accumulator width whose observed persistent-overflow
    /// fraction stays within `budget` (0.0 = no observed overflow at all).
    /// Binary search over the monotone predicate
    /// `dots_over_width(p) <= budget * dots`; `None` when the histogram
    /// is empty.
    pub fn calibrated_bits(&self, budget: f64) -> Option<u32> {
        let total = self.hist_dots();
        if total == 0 {
            return None;
        }
        let allowed = (budget.max(0.0) * total as f64).floor() as u64;
        let (mut lo, mut hi) = (2u32, (BITS_HIST_BUCKETS - 1) as u32);
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.dots_over_width(mid) <= allowed {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        Some(lo)
    }

    /// Fraction of overflowing dots that are transient (Fig. 2a).
    pub fn transient_fraction(&self) -> f64 {
        let total = self.transient_dots + self.persistent_dots;
        if total == 0 {
            0.0
        } else {
            self.transient_dots as f64 / total as f64
        }
    }

    /// Fraction of transient dots the policy resolved (paper §3.2: 99.8%).
    pub fn resolved_transient_fraction(&self) -> f64 {
        if self.transient_dots == 0 {
            return 1.0;
        }
        // policy events on transient dots = policy_event_dots minus the
        // persistent ones (persistent dots always have policy events under
        // clipping policies)
        let unresolved = self.policy_event_dots.saturating_sub(self.persistent_dots);
        1.0 - (unresolved.min(self.transient_dots) as f64 / self.transient_dots as f64)
    }
}

/// Per-layer + aggregate report for one evaluation run.
#[derive(Clone, Debug, Default)]
pub struct OverflowReport {
    pub layers: Vec<(String, OverflowStats)>,
}

impl OverflowReport {
    /// Stats of one layer, if present.
    pub fn layer(&self, name: &str) -> Option<&OverflowStats> {
        self.layers.iter().find(|(n, _)| n == name).map(|(_, s)| s)
    }

    pub fn layer_mut(&mut self, name: &str) -> &mut OverflowStats {
        if let Some(i) = self.layers.iter().position(|(n, _)| n == name) {
            &mut self.layers[i].1
        } else {
            self.layers.push((name.to_string(), OverflowStats::default()));
            &mut self.layers.last_mut().unwrap().1
        }
    }

    pub fn total(&self) -> OverflowStats {
        let mut t = OverflowStats::default();
        for (_, s) in &self.layers {
            t.merge(s);
        }
        t
    }

    pub fn merge(&mut self, o: &OverflowReport) {
        for (name, s) in &o.layers {
            self.layer_mut(name).merge(s);
        }
    }

    pub fn print(&self) {
        println!(
            "{:<14} {:>10} {:>10} {:>10} {:>10} {:>10}",
            "layer", "dots", "naive-ovf", "transient", "persist", "policy-ovf"
        );
        for (name, s) in &self.layers {
            println!(
                "{:<14} {:>10} {:>10} {:>10} {:>10} {:>10}",
                name, s.dots, s.naive_event_dots, s.transient_dots, s.persistent_dots,
                s.policy_event_dots
            );
        }
        let t = self.total();
        println!(
            "{:<14} {:>10} {:>10} {:>10} {:>10} {:>10}",
            "TOTAL", t.dots, t.naive_event_dots, t.transient_dots, t.persistent_dots,
            t.policy_event_dots
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates() {
        let mut a = OverflowStats { dots: 10, transient_dots: 2, ..Default::default() };
        let b = OverflowStats { dots: 5, transient_dots: 1, persistent_dots: 4, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.dots, 15);
        assert_eq!(a.transient_dots, 3);
        assert_eq!(a.persistent_dots, 4);
    }

    #[test]
    fn fractions() {
        let s = OverflowStats { transient_dots: 3, persistent_dots: 97, ..Default::default() };
        assert!((s.transient_fraction() - 0.03).abs() < 1e-12);
        let clean = OverflowStats::default();
        assert_eq!(clean.transient_fraction(), 0.0);
        assert_eq!(clean.resolved_transient_fraction(), 1.0);
    }

    #[test]
    fn required_bits_histogram_and_budget_search() {
        let mut s = OverflowStats::default();
        // 90 dots fit 12 bits, 9 need 14, 1 needs 20
        for _ in 0..90 {
            s.record_required_bits(12);
        }
        for _ in 0..9 {
            s.record_required_bits(14);
        }
        s.record_required_bits(20);
        assert_eq!(s.hist_dots(), 100);
        assert_eq!(s.max_required_bits(), 20);
        assert_eq!(s.dots_over_width(20), 0);
        assert_eq!(s.dots_over_width(14), 1);
        assert_eq!(s.dots_over_width(12), 10);
        assert_eq!(s.dots_over_width(11), 100);
        // zero budget: the width that holds everything observed
        assert_eq!(s.calibrated_bits(0.0), Some(20));
        // 1% budget tolerates the single 20-bit dot
        assert_eq!(s.calibrated_bits(0.01), Some(14));
        // 10% budget also tolerates the 14-bit dots
        assert_eq!(s.calibrated_bits(0.10), Some(12));
        assert_eq!(OverflowStats::default().calibrated_bits(0.0), None);
        // merge adds histograms elementwise
        let mut t = OverflowStats::default();
        t.record_required_bits(12);
        t.merge(&s);
        assert_eq!(t.bits_hist[12], 91);
        assert_eq!(t.hist_dots(), 101);
    }

    #[test]
    fn report_layers() {
        let mut r = OverflowReport::default();
        r.layer_mut("conv0").dots += 7;
        r.layer_mut("conv0").dots += 3;
        r.layer_mut("fc").dots += 5;
        assert_eq!(r.layers.len(), 2);
        assert_eq!(r.total().dots, 15);
        let mut r2 = OverflowReport::default();
        r2.layer_mut("fc").dots = 1;
        r.merge(&r2);
        assert_eq!(r.total().dots, 16);
    }
}
