//! Overflow statistics collection (paper §3.1 and §5.0.1 — the
//! "library for analyzing overflows").
//!
//! Every dot product evaluated by the engine can be classified as clean,
//! transient (naive order overflows but the exact result fits) or
//! persistent (the result itself cannot fit). Reports aggregate per layer
//! and over a whole evaluation.

/// Counters over a set of dot products at one accumulator width.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OverflowStats {
    /// dot products evaluated
    pub dots: u64,
    /// dots whose naive (index-order) accumulation had >= 1 overflow event
    pub naive_event_dots: u64,
    /// total naive overflow events
    pub naive_events: u64,
    /// dots with a transient overflow (naive events but exact fits)
    pub transient_dots: u64,
    /// dots with a persistent overflow (exact result out of range)
    pub persistent_dots: u64,
    /// dots where the *selected policy* still had >= 1 event
    pub policy_event_dots: u64,
    /// partial products processed (dot lengths summed, zeros skipped)
    pub products: u64,
}

impl OverflowStats {
    pub fn merge(&mut self, o: &OverflowStats) {
        self.dots += o.dots;
        self.naive_event_dots += o.naive_event_dots;
        self.naive_events += o.naive_events;
        self.transient_dots += o.transient_dots;
        self.persistent_dots += o.persistent_dots;
        self.policy_event_dots += o.policy_event_dots;
        self.products += o.products;
    }

    /// Fraction of overflowing dots that are transient (Fig. 2a).
    pub fn transient_fraction(&self) -> f64 {
        let total = self.transient_dots + self.persistent_dots;
        if total == 0 {
            0.0
        } else {
            self.transient_dots as f64 / total as f64
        }
    }

    /// Fraction of transient dots the policy resolved (paper §3.2: 99.8%).
    pub fn resolved_transient_fraction(&self) -> f64 {
        if self.transient_dots == 0 {
            return 1.0;
        }
        // policy events on transient dots = policy_event_dots minus the
        // persistent ones (persistent dots always have policy events under
        // clipping policies)
        let unresolved = self.policy_event_dots.saturating_sub(self.persistent_dots);
        1.0 - (unresolved.min(self.transient_dots) as f64 / self.transient_dots as f64)
    }
}

/// Per-layer + aggregate report for one evaluation run.
#[derive(Clone, Debug, Default)]
pub struct OverflowReport {
    pub layers: Vec<(String, OverflowStats)>,
}

impl OverflowReport {
    pub fn layer_mut(&mut self, name: &str) -> &mut OverflowStats {
        if let Some(i) = self.layers.iter().position(|(n, _)| n == name) {
            &mut self.layers[i].1
        } else {
            self.layers.push((name.to_string(), OverflowStats::default()));
            &mut self.layers.last_mut().unwrap().1
        }
    }

    pub fn total(&self) -> OverflowStats {
        let mut t = OverflowStats::default();
        for (_, s) in &self.layers {
            t.merge(s);
        }
        t
    }

    pub fn merge(&mut self, o: &OverflowReport) {
        for (name, s) in &o.layers {
            self.layer_mut(name).merge(s);
        }
    }

    pub fn print(&self) {
        println!(
            "{:<14} {:>10} {:>10} {:>10} {:>10} {:>10}",
            "layer", "dots", "naive-ovf", "transient", "persist", "policy-ovf"
        );
        for (name, s) in &self.layers {
            println!(
                "{:<14} {:>10} {:>10} {:>10} {:>10} {:>10}",
                name, s.dots, s.naive_event_dots, s.transient_dots, s.persistent_dots,
                s.policy_event_dots
            );
        }
        let t = self.total();
        println!(
            "{:<14} {:>10} {:>10} {:>10} {:>10} {:>10}",
            "TOTAL", t.dots, t.naive_event_dots, t.transient_dots, t.persistent_dots,
            t.policy_event_dots
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates() {
        let mut a = OverflowStats { dots: 10, transient_dots: 2, ..Default::default() };
        let b = OverflowStats { dots: 5, transient_dots: 1, persistent_dots: 4, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.dots, 15);
        assert_eq!(a.transient_dots, 3);
        assert_eq!(a.persistent_dots, 4);
    }

    #[test]
    fn fractions() {
        let s = OverflowStats { transient_dots: 3, persistent_dots: 97, ..Default::default() };
        assert!((s.transient_fraction() - 0.03).abs() < 1e-12);
        let clean = OverflowStats::default();
        assert_eq!(clean.transient_fraction(), 0.0);
        assert_eq!(clean.resolved_transient_fraction(), 1.0);
    }

    #[test]
    fn report_layers() {
        let mut r = OverflowReport::default();
        r.layer_mut("conv0").dots += 7;
        r.layer_mut("conv0").dots += 3;
        r.layer_mut("fc").dots += 5;
        assert_eq!(r.layers.len(), 2);
        assert_eq!(r.total().dots, 15);
        let mut r2 = OverflowReport::default();
        r2.layer_mut("fc").dots = 1;
        r.merge(&r2);
        assert_eq!(r.total().dots, 16);
    }
}
