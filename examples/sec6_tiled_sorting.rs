//! Reproduce the paper's §3.2 / §6 claims on MobileNetV2:
//! * one sorting round resolves ~99.8% of transient overflows;
//! * tiled sorting with k=256 still resolves ~99% (software scheduling).
//!
//!     cargo run --release --offline --example sec6_tiled_sorting
//!     (--model NAME, --acc-bits P, --limit N, --tiles 8,16,...)

use pqs::figures::{self, sec6};
use pqs::formats::manifest::Manifest;
use pqs::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let man = Manifest::load_default()?;
    let model = match args.get("model") {
        Some(m) => m.to_string(),
        None => sec6::default_model(&man).expect("no mbv2 pq model in manifest"),
    };
    let acc_bits = args.get_u32("acc-bits", 16);
    let limit = args.get_usize("limit", figures::eval_limit(64));
    let tiles: Vec<usize> = args
        .get("tiles")
        .map(|s| s.split(',').filter_map(|v| v.parse().ok()).collect())
        .unwrap_or_else(|| vec![8, 16, 32, 64, 128, 256, 0]);
    let r = sec6::run(&man, &model, acc_bits, &tiles, limit)?;
    sec6::print(&r);
    println!(
        "\npaper shape check: resolution stays ~99% down to tile 256 and only \
         degrades at small tiles — sorting composes with cache blocking."
    );
    Ok(())
}
