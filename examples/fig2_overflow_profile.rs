//! Reproduce paper Figure 2: overflow profile + accuracy of a 1-layer MLP
//! (8-bit weights/activations) vs accumulator bitwidth.
//!
//!     cargo run --release --offline --example fig2_overflow_profile
//!
//! Flags: --limit N (test samples per point), --from P --to P (bit range).

use pqs::figures::{self, fig2};
use pqs::formats::manifest::Manifest;
use pqs::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let man = Manifest::load_default()?;
    let limit = args.get_usize("limit", figures::eval_limit(512));
    let from = args.get_u32("from", 12);
    let to = args.get_u32("to", 21);
    let r = fig2::run(&man, limit, from..=to)?;
    fig2::print(&r);
    println!(
        "\npaper shape check: transient share of overflows is small at low p \
         (paper: 3-24% at 13-16b), yet resolving them (oracle) lifts accuracy \
         well above clip; sorted matches oracle."
    );
    Ok(())
}
