//! Reproduce paper Figure 3: P->Q vs Q->P training under low-rank weight
//! approximations (2-layer MLP, N:M pruning with M=32).
//!
//!     cargo run --release --offline --example fig3_lowrank_pq_qp
//!
//! Accuracies come from the python QAT runs (this is a training-schedule
//! comparison); the rust engine re-verifies a subset end-to-end.

use pqs::figures::{self, fig3};
use pqs::formats::manifest::Manifest;
use pqs::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let man = Manifest::load_default()?;
    let limit = args.get_usize("limit", figures::eval_limit(512));
    let verify_every = args.get_usize("verify-every", 4);
    let rows = fig3::run(&man, limit, verify_every)?;
    fig3::print(&rows);

    // paper-shape summary: mean accuracy per schedule at the harshest rank
    let mut by_sched: std::collections::BTreeMap<(String, String), (f64, usize)> = Default::default();
    for r in &rows {
        let e = by_sched.entry((r.schedule.clone(), r.rank.clone())).or_insert((0.0, 0));
        e.0 += r.acc_python;
        e.1 += 1;
    }
    println!("\nmean accuracy by (schedule, rank):");
    for ((s, k), (sum, n)) in &by_sched {
        println!("  {s:>3} rank {k:>5}: {:.3}", sum / *n as f64);
    }
    println!(
        "\npaper shape check: P->Q stays above Q->P as rank shrinks — FP32 \
         weights are the better pruning signal."
    );
    Ok(())
}
