//! Reproduce paper Figure 5: accuracy vs accumulator bitwidth — the PQS
//! pareto frontier against A2Q and against clipping (magenta lines), plus
//! the headline claim (accumulator bitwidth reduction at FP32-par accuracy).
//!
//!     cargo run --release --offline --example fig5_pareto
//!     (use --arch mlp2|resnet_tiny|mbv2_tiny to restrict; --limit N)

use pqs::figures::{self, fig5};
use pqs::formats::manifest::Manifest;
use pqs::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let man = Manifest::load_default()?;
    let limit = args.get_usize("limit", figures::eval_limit(192));
    let widths: Vec<u32> = args
        .get("widths")
        .map(|s| s.split(',').filter_map(|v| v.parse().ok()).collect())
        .unwrap_or_else(|| vec![12, 13, 14, 15, 16, 18, 20]);
    let pts = fig5::run(&man, limit, &widths, args.get("arch"))?;
    fig5::print(&pts);

    let mut archs: Vec<String> = pts.iter().map(|p| p.arch.clone()).collect();
    archs.sort();
    archs.dedup();
    println!("\n=== headline: lowest accumulator width within 2% of FP32 baseline ===");
    for arch in &archs {
        match fig5::min_width_within(&pts, arch, 0.02) {
            Some((p, acc, base)) => println!(
                "{arch:>12}: p={p} (acc {acc:.3}, fp32 {base:.3}) — {:.1}x reduction vs 32-bit",
                32.0 / p as f64
            ),
            None => println!("{arch:>12}: no width within tolerance in sweep"),
        }
    }
    println!(
        "\npaper shape check: PQS (sorted) reaches lower p than A2Q at equal or \
         better accuracy; clip-only (magenta) needs ~4 more bits than sorted."
    );
    Ok(())
}
