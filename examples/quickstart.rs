//! Quickstart: load a trained PQS model, run bit-accurate inference with a
//! narrow accumulator, and see why sorting matters.
//!
//!     cargo run --release --offline --example quickstart
//!
//! (run `make artifacts` once first.)

use pqs::accum::Policy;
use pqs::coordinator::EvalService;
use pqs::data::Dataset;
use pqs::formats::manifest::Manifest;
use pqs::models;
use pqs::nn::engine::{Engine, EngineConfig};

fn main() -> anyhow::Result<()> {
    // 1. artifacts: the experiment manifest indexes every trained model
    let man = Manifest::load_default()?;
    println!("artifacts at {:?} — {} models", man.dir, man.models.len());

    // 2. load a pruned + quantized model (87.5% sparse 2-layer MLP)
    let name = "mlp2_pq_s875_w8a8_kfull";
    let model = models::load(&man, name)?;
    println!("{}", models::describe(&model));

    // 3. classify a few test images with a 15-bit accumulator
    let ds = Dataset::load(man.dataset_path(&man.test_dataset_for(&model.arch)?.test))?;
    let imgs = ds.images_f32(0, 4);
    let mut engine = Engine::new(
        &model,
        EngineConfig { policy: Policy::Sorted, acc_bits: 15, ..Default::default() },
    );
    let out = engine.forward(&imgs, 4)?;
    for i in 0..4 {
        println!(
            "image {i}: predicted {} (true {})",
            out.argmax(i),
            ds.labels[i]
        );
    }

    // 4. the point of the paper: at the same 15 bits, clipping the
    //    accumulations destroys the model, sorting keeps it alive
    for policy in [Policy::Clip, Policy::Sorted] {
        let svc = EvalService::new(
            &model,
            EngineConfig { policy, acc_bits: 15, ..Default::default() },
        );
        let r = svc.evaluate(&ds, Some(512))?;
        println!(
            "15-bit accumulator, {:>6}: accuracy {:.3}  ({:.0} img/s)",
            policy.name(),
            r.accuracy,
            r.throughput_ips
        );
    }
    println!("fp32 baseline (python): {:.3}", model.acc_fp32);
    Ok(())
}
