//! End-to-end driver (DESIGN.md §deliverables): batched online inference
//! through the full stack, on a real workload.
//!
//! * loads a trained, pruned, quantized model (`.pqsw` artifact);
//! * serves 1024 classification requests through the coordinator's dynamic
//!   batcher with the PQS sorted 16-bit accumulation engine, reporting
//!   latency percentiles + throughput + accuracy;
//! * runs the same batch through the AOT-compiled HLO (Layer-1 Pallas
//!   kernel, PJRT runtime) and cross-checks predictions — proving all
//!   three layers compose.
//!
//!     cargo run --release --offline --example serve

use pqs::accum::Policy;
use pqs::coordinator::{serve_requests, Request};
use pqs::data::Dataset;
use pqs::formats::manifest::Manifest;
use pqs::models;
use pqs::nn::engine::{Engine, EngineConfig};
use pqs::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let man = Manifest::load_default()?;
    let name = man.experiments["fig2"][0].clone(); // mlp1, 8/8
    let model = models::load(&man, &name)?;
    let ds = Dataset::load(man.dataset_path(&man.test_dataset_for(&model.arch)?.test))?;
    println!("serving model: {}", models::describe(&model));

    // ---- engine path: dynamic batching over the evaluation coordinator --
    let n = ds.n.min(1024);
    let dim = ds.dim();
    let imgs = ds.images_f32(0, n);
    let requests: Vec<Request> = (0..n)
        .map(|i| Request { id: i as u64, image: imgs[i * dim..(i + 1) * dim].to_vec() })
        .collect();
    let cfg = EngineConfig { policy: Policy::Sorted, acc_bits: 16, ..Default::default() };
    let threads = pqs::util::pool::default_threads();
    let (resp, metrics) = serve_requests(&model, cfg, requests, 32, threads)?;
    let correct = resp.iter().filter(|r| r.class == ds.labels[r.id as usize] as usize).count();
    println!("\n-- engine path (sorted, 16-bit accumulator, batch<=32, {threads} threads) --");
    metrics.print();
    println!("accuracy {:.3} over {} requests", correct as f64 / n as f64, n);

    // ---- PJRT path: the AOT artifact built around the Pallas kernel -----
    println!("\n-- PJRT path (artifacts/model.hlo.txt: Pallas sorted1 kernel, p=16) --");
    let rt = Runtime::cpu()?;
    let exe = rt.load_hlo(man.dir.join("model.hlo.txt"))?;
    let batch = 8;
    let mut agree = 0usize;
    let mut served = 0usize;
    let mut engine = Engine::new(
        &model,
        EngineConfig { policy: Policy::Sorted1, acc_bits: 16, ..Default::default() },
    );
    let t0 = std::time::Instant::now();
    let mut hlo_ovf_total = 0f32;
    for b in 0..(n / batch).min(16) {
        let chunk = ds.images_f32(b * batch, batch);
        let outs = exe.run_f32(&chunk, &[batch, ds.c, ds.h, ds.w])?;
        hlo_ovf_total += outs[1][0];
        let eng_out = engine.forward(&chunk, batch)?;
        for i in 0..batch {
            let row = &outs[0][i * 10..(i + 1) * 10];
            let top = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if top == eng_out.argmax(i) {
                agree += 1;
            }
            served += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "PJRT served {served} images in {:.1} ms ({:.0} img/s incl. engine cross-check)",
        dt * 1e3,
        served as f64 / dt
    );
    println!("engine<->HLO top-1 agreement: {agree}/{served}");
    println!("HLO-reported overflow events (16-bit sorted1): {hlo_ovf_total:.0}");
    assert_eq!(agree, served, "layers disagree!");
    println!("\nall three layers agree — stack verified.");
    Ok(())
}
