//! End-to-end serving driver (DESIGN.md §deliverables): batched online
//! inference through the persistent `Server` runtime.
//!
//! Four phases:
//! 1. **serve** — classification requests flow through the bounded queue
//!    and the streaming dynamic batcher (per-request latency percentiles,
//!    accuracy when real artifacts/labels are available);
//! 2. **soak** — a 10k-synthetic-request flood through the bounded queue
//!    (backpressure + dynamic batching under load, no panics, per-request
//!    latency percentiles);
//! 3. **HTTP front-end** — a TWO-model router behind the hand-rolled
//!    HTTP/1.1 server: keep-alive `POST /v1/classify` over loopback TCP
//!    hitting the default model, `"model"`-routed requests hitting the
//!    second (lazily loaded) model, `GET /v1/models` reflecting load
//!    state, an unknown model answered with 404, a malformed request
//!    answered with 400, an already-expired deadline answered with 504
//!    (the `expired` metric increments), all without killing the
//!    listener;
//! 4. **PJRT cross-check** — the same batch through the AOT-compiled HLO
//!    (Layer-1 Pallas kernel), proving all three layers compose. Skipped
//!    gracefully when the build has no PJRT backend or artifacts are
//!    absent.
//!
//! Works with or without artifacts: without them, a synthetic model keeps
//! the serving-path demonstration (and the soak) fully runnable.
//!
//!     cargo run --release --offline --example serve
//!     (flags: --threads N --max-batch B --queue-cap Q --soak N)

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail};
use pqs::accum::Policy;
use pqs::coordinator::{
    ModelRegistry, ModelSource, Router, RouterConfig, Server, ServerConfig, SubmitError,
    SyntheticSpec,
};
use pqs::data::Dataset;
use pqs::formats::manifest::Manifest;
use pqs::http::{HttpConfig, HttpServer};
use pqs::models;
use pqs::nn::engine::{Engine, EngineConfig};
use pqs::runtime::Runtime;
use pqs::util::cli::Args;
use pqs::util::json::Json;
use pqs::util::rng::Pcg32;

/// Minimal blocking HTTP client for the phase-3 demo: keeps one socket
/// open and reads Content-Length-framed responses off it.
struct MiniClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl MiniClient {
    fn connect(addr: std::net::SocketAddr) -> anyhow::Result<MiniClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_secs(10)))?;
        Ok(MiniClient { stream, buf: Vec::new() })
    }

    fn request(&mut self, raw: &[u8]) -> anyhow::Result<(u16, Json)> {
        self.stream.write_all(raw)?;
        let mut tmp = [0u8; 4096];
        loop {
            if let Some(he) = self.buf.windows(4).position(|w| w == b"\r\n\r\n") {
                let head_end = he + 4;
                let head = std::str::from_utf8(&self.buf[..head_end])?.to_string();
                let status: u16 = head
                    .split(' ')
                    .nth(1)
                    .ok_or_else(|| anyhow!("bad status line"))?
                    .parse()?;
                let mut body_len = 0usize;
                for line in head.lines().skip(1) {
                    if let Some((k, v)) = line.split_once(':') {
                        if k.eq_ignore_ascii_case("content-length") {
                            body_len = v.trim().parse()?;
                        }
                    }
                }
                while self.buf.len() < head_end + body_len {
                    let n = self.stream.read(&mut tmp)?;
                    if n == 0 {
                        bail!("eof mid-body");
                    }
                    self.buf.extend_from_slice(&tmp[..n]);
                }
                let body = Json::parse_bytes(&self.buf[head_end..head_end + body_len])
                    .map_err(|e| anyhow!("bad json body: {e}"))?;
                self.buf.drain(..head_end + body_len);
                return Ok((status, body));
            }
            let n = self.stream.read(&mut tmp)?;
            if n == 0 {
                bail!("eof before response head");
            }
            self.buf.extend_from_slice(&tmp[..n]);
        }
    }
}

fn classify_request(image: &[f32], id: u64, deadline_ms: Option<f64>) -> Vec<u8> {
    classify_request_for(image, id, deadline_ms, None)
}

fn classify_request_for(
    image: &[f32],
    id: u64,
    deadline_ms: Option<f64>,
    model: Option<&str>,
) -> Vec<u8> {
    let nums: Vec<String> = image.iter().map(|v| format!("{v}")).collect();
    let deadline = deadline_ms.map(|d| format!(",\"deadline_ms\":{d}")).unwrap_or_default();
    let model = model.map(|m| format!(",\"model\":\"{m}\"")).unwrap_or_default();
    let body = format!("{{\"id\":{id},\"image\":[{}]{deadline}{model}}}", nums.join(","));
    format!(
        "POST /v1/classify HTTP/1.1\r\nHost: serve\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let threads = args.get_usize("threads", pqs::util::pool::default_threads());
    let max_batch = args.get_usize("max-batch", 32);
    let queue_cap = args.get_usize("queue-cap", 512);
    let soak_n = args.get_usize("soak", 10_000);
    let cfg = EngineConfig { policy: Policy::Sorted, acc_bits: 16, ..Default::default() };
    let scfg = ServerConfig {
        threads,
        max_batch,
        queue_cap,
        linger: Duration::from_micros(200),
        engine_threads: 1,
        default_deadline: None,
    };

    // ---- load real artifacts when present, else a synthetic model -------
    let artifacts = Manifest::load_default().ok();
    let (model, ds) = match &artifacts {
        Some(man) => {
            let name = man.experiments["fig2"][0].clone(); // mlp1, 8/8
            let model = models::load(man, &name)?;
            let ds = Dataset::load(man.dataset_path(&man.test_dataset_for(&model.arch)?.test))?;
            (model, Some(ds))
        }
        None => {
            println!("(artifacts not found — using a synthetic model; run `make artifacts` for the real one)");
            (models::synthetic_linear(784, 10), None)
        }
    };
    println!("serving model: {}", models::describe(&model));
    let dim: usize = model.input_shape.iter().product();

    // ---- phase 1: serve requests through the persistent runtime ---------
    let n = ds.as_ref().map(|d| d.n.min(1024)).unwrap_or(1024);
    let images: Vec<f32> = match &ds {
        Some(d) => d.images_f32(0, n),
        None => {
            let mut rng = Pcg32::new(0x5EED);
            (0..n * dim).map(|_| rng.f32()).collect()
        }
    };
    let srv = Server::start(&model, cfg, scfg);
    let pending: Vec<_> = (0..n)
        .map(|i| {
            srv.submit(i as u64, images[i * dim..(i + 1) * dim].to_vec(), None)
                .expect("server accepts while open")
        })
        .collect();
    let mut classes = vec![0usize; n];
    for p in pending {
        let r = p.wait();
        classes[r.id as usize] = r.result.expect("well-formed request");
    }
    let metrics = srv.shutdown();
    println!(
        "\n-- engine path (sorted, 16-bit accumulator, batch<={max_batch}, {threads} workers) --"
    );
    metrics.print();
    if let Some(d) = &ds {
        let correct = (0..n).filter(|&i| classes[i] == d.labels[i] as usize).count();
        println!("accuracy {:.3} over {} requests", correct as f64 / n as f64, n);
    } else {
        // no labels: verify against the offline engine instead
        let mut eng = Engine::new(&model, cfg);
        let out = eng.forward(&images, n)?;
        let agree = (0..n).filter(|&i| classes[i] == out.argmax(i)).count();
        assert_eq!(agree, n, "server must match the offline engine");
        println!("server<->offline-engine agreement: {agree}/{n}");
    }

    // ---- phase 2: 10k-synthetic-request soak through the bounded queue --
    println!("\n-- soak: {soak_n} synthetic requests (queue_cap {queue_cap}) --");
    let srv = Server::start(&model, cfg, scfg);
    let mut rng = Pcg32::new(0xB10B);
    let base: Vec<Vec<f32>> =
        (0..64).map(|_| (0..dim).map(|_| rng.f32()).collect()).collect();
    let mut pending = Vec::with_capacity(soak_n);
    let mut shed = 0usize;
    for i in 0..soak_n {
        let img = base[i % base.len()].clone();
        // fast path first; fall back to blocking submit under backpressure
        match srv.try_submit(i as u64, img, None) {
            Ok(p) => pending.push(p),
            Err(SubmitError::Full(img)) => {
                shed += 1;
                match srv.submit(i as u64, img, None) {
                    Ok(p) => pending.push(p),
                    Err(_) => unreachable!("server is open"),
                }
            }
            Err(SubmitError::Closed(_)) => unreachable!("server is open"),
        }
    }
    let mut ok = 0usize;
    for p in pending {
        if p.wait().result.is_ok() {
            ok += 1;
        }
    }
    let metrics = srv.shutdown();
    metrics.print();
    println!(
        "soak complete: {ok}/{soak_n} ok, {shed} submissions hit backpressure, no panics"
    );
    assert_eq!(ok, soak_n, "soak must answer every request");

    // ---- phase 3: two-model router behind the HTTP/1.1 front-end --------
    println!("\n-- HTTP front-end: 2-model router, keep-alive POST /v1/classify --");
    let aux_spec = SyntheticSpec::Conv { c: 2, h: 8, w: 8, oc: 4, classes: 10 };
    let aux_model = pqs::models::synthetic_conv(2, 8, 8, 4, 10);
    let aux_dim: usize = aux_model.input_shape.iter().product();
    let mut registry = ModelRegistry::new();
    registry.register("primary", ModelSource::Memory(model.clone()));
    registry.register("aux", ModelSource::Synthetic(aux_spec));
    let rcfg = RouterConfig { max_loaded: 0, engine: cfg, server: scfg, preload: Vec::new() };
    let router = Router::new(registry, rcfg)?;
    let http = HttpServer::start(router, "127.0.0.1:0", HttpConfig::default())?;
    println!("bound http://{}", http.local_addr());
    let mut client = MiniClient::connect(http.local_addr())?;
    // the fleet listing knows both models before anything is loaded
    let (status, body) = client.request(b"GET /v1/models HTTP/1.1\r\nHost: serve\r\n\r\n")?;
    assert_eq!(status, 200);
    assert_eq!(body.get("default").and_then(Json::as_str), Some("primary"));
    let listed = body.get("models").and_then(Json::as_arr).map(|a| a.len()).unwrap_or(0);
    assert_eq!(listed, 2, "GET /v1/models must list the registered fleet");
    let http_n = 16.min(n);
    let mut agree = 0usize;
    for i in 0..http_n {
        let image = &images[i * dim..(i + 1) * dim];
        let (status, body) = client.request(&classify_request(image, i as u64, None))?;
        assert_eq!(status, 200, "well-formed request must classify");
        let class = body
            .get("class")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("response missing class"))?;
        if class == classes[i] {
            agree += 1;
        }
    }
    println!("HTTP<->engine agreement over one keep-alive connection: {agree}/{http_n}");
    assert_eq!(agree, http_n, "HTTP path must match the engine-path classes");
    // "model"-routed request: the aux CNN loads lazily and classifies like
    // a dedicated offline engine
    let mut rng = Pcg32::new(0xA0A);
    let aux_img: Vec<f32> = (0..aux_dim).map(|_| rng.f32()).collect();
    let (status, body) =
        client.request(&classify_request_for(&aux_img, 500, None, Some("aux")))?;
    assert_eq!(status, 200, "routed request must classify");
    let aux_class = body.get("class").and_then(Json::as_usize);
    let mut aux_eng = Engine::new(&aux_model, cfg);
    let want = aux_eng.forward(&aux_img, 1)?.argmax(0);
    assert_eq!(aux_class, Some(want), "routed class must match the dedicated engine");
    println!("model-routed request served by the lazily loaded aux model (class {want})");
    // unknown model: 404 naming the fleet, connection survives
    let (status, body) =
        client.request(&classify_request_for(&aux_img, 501, None, Some("nope")))?;
    assert_eq!(status, 404, "unknown model must answer 404");
    let err = body.get("error").and_then(Json::as_str).unwrap_or("");
    assert!(err.contains("aux"), "404 body must list the registered models: {err}");
    // malformed body: 400, and the connection/listener survive
    let bad = b"POST /v1/classify HTTP/1.1\r\nContent-Length: 9\r\n\r\n{not json";
    let (status, _) = client.request(bad)?;
    assert_eq!(status, 400, "malformed JSON must answer 400");
    // an already-expired deadline: 504 without touching an engine
    let image = &images[..dim];
    let (status, body) = client.request(&classify_request(image, 9_999, Some(0.0)))?;
    assert_eq!(status, 504, "expired deadline must answer 504");
    println!(
        "expired-deadline request answered 504 ({})",
        body.get("error").and_then(Json::as_str).unwrap_or("?")
    );
    let report = http.shutdown();
    report.print();
    let total = report.router.aggregate();
    assert!(total.expired >= 1, "expired counter must increment");
    assert_eq!(report.router.unknown_model, 1, "unknown-model counter must increment");

    // ---- phase 4: PJRT path (AOT artifact around the Pallas kernel) -----
    println!("\n-- PJRT path (artifacts/model.hlo.txt: Pallas sorted1 kernel, p=16) --");
    match (&artifacts, Runtime::available()) {
        (Some(man), true) => {
            let ds = ds.as_ref().expect("artifacts imply dataset");
            let rt = Runtime::cpu()?;
            let exe = rt.load_hlo(man.dir.join("model.hlo.txt"))?;
            let batch = 8;
            let mut agree = 0usize;
            let mut served = 0usize;
            let mut engine = Engine::new(
                &model,
                EngineConfig { policy: Policy::Sorted1, acc_bits: 16, ..Default::default() },
            );
            let t0 = std::time::Instant::now();
            let mut hlo_ovf_total = 0f32;
            for b in 0..(n / batch).min(16) {
                let chunk = ds.images_f32(b * batch, batch);
                let outs = exe.run_f32(&chunk, &[batch, ds.c, ds.h, ds.w])?;
                hlo_ovf_total += outs[1][0];
                let eng_out = engine.forward(&chunk, batch)?;
                for i in 0..batch {
                    let row = &outs[0][i * 10..(i + 1) * 10];
                    let top = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    if top == eng_out.argmax(i) {
                        agree += 1;
                    }
                    served += 1;
                }
            }
            let dt = t0.elapsed().as_secs_f64();
            println!(
                "PJRT served {served} images in {:.1} ms ({:.0} img/s incl. engine cross-check)",
                dt * 1e3,
                served as f64 / dt
            );
            println!("engine<->HLO top-1 agreement: {agree}/{served}");
            println!("HLO-reported overflow events (16-bit sorted1): {hlo_ovf_total:.0}");
            assert_eq!(agree, served, "layers disagree!");
            println!("\nall three layers agree — stack verified.");
        }
        (None, _) => println!("skipped: artifacts not built (run `make artifacts`)"),
        (_, false) => {
            println!("skipped: built without the `pjrt` feature (xla crate unavailable offline)")
        }
    }
    Ok(())
}
