//! Reproduce paper Figure 4: P->Q vs Q->P vs structured filter pruning on
//! the CNNs (ResNet-tiny / MobileNetV2-tiny, N:M with M=16).
//!
//!     cargo run --release --offline --example fig4_schedules_cnn

use pqs::figures::{self, fig4};
use pqs::formats::manifest::Manifest;
use pqs::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let man = Manifest::load_default()?;
    let limit = args.get_usize("limit", figures::eval_limit(128));
    let verify_every = args.get_usize("verify-every", 6);
    let rows = fig4::run(&man, limit, verify_every)?;
    fig4::print(&rows);
    println!(
        "\npaper shape check: P->Q >= Q->P across sparsities; filter pruning \
         (structured) degrades fastest — N:M is the usable middle ground."
    );
    Ok(())
}
